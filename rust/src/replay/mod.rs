//! Replay buffer: fixed-capacity ring buffer over transitions, with
//! optional fp16 storage (halving the dominant memory consumer, as the
//! paper's Table 3 exploits), byte-packed u8 pixel storage (quartering
//! it — envs emit u8-range subpixels, so 1 byte per subpixel loses
//! nothing on the pixel grid), and DRQ-style random-crop augmentation
//! for the pixel agent.

use crate::lowp::HalfFormat;
use crate::rngs::Pcg64;
use crate::sac::Batch;

/// How observations/actions are stored.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Storage {
    F32,
    /// IEEE binary16 words — bit-exact with fp16 hardware storage.
    F16,
    /// One byte per value on the `k/255` pixel grid. Observations only:
    /// action rows stay f32 (actions are not pixels). Exact for values
    /// the envs actually emit (`u8 / 255`); off-grid values quantize to
    /// the nearest grid point (max error `1/510`).
    U8,
}

/// Round `x` onto the `k/255` grid and return the byte index. Saturates
/// outside `[0, 1]`; NaN maps to 0 (the saturating float→int cast).
#[inline]
fn u8_encode(x: f32) -> u8 {
    (x * 255.0).round() as u8
}

/// Widen a stored byte back to f32. Division (not multiplication by a
/// rounded `1/255`) so `decode(encode(k/255)) == k/255` bitwise.
#[inline]
fn u8_decode(u: u8) -> f32 {
    u as f32 / 255.0
}

/// Internal storage vector that is f32, packed f16, or pixel bytes.
#[derive(Debug, Clone)]
enum Buf {
    F32(Vec<f32>),
    F16(Vec<u16>),
    U8(Vec<u8>),
}

impl Buf {
    fn new(storage: Storage, n: usize) -> Self {
        match storage {
            Storage::F32 => Buf::F32(vec![0.0; n]),
            Storage::F16 => Buf::F16(vec![0; n]),
            Storage::U8 => Buf::U8(vec![0; n]),
        }
    }

    #[inline]
    fn write(&mut self, off: usize, src: &[f32]) {
        match self {
            Buf::F32(v) => v[off..off + src.len()].copy_from_slice(src),
            // SIMD pack on AVX2/F16C hosts, bitwise equal to the scalar
            // encode loop this replaces
            Buf::F16(v) => HalfFormat::F16.pack_slice(src, &mut v[off..off + src.len()]),
            Buf::U8(v) => {
                for (d, &s) in v[off..off + src.len()].iter_mut().zip(src) {
                    *d = u8_encode(s);
                }
            }
        }
    }

    #[inline]
    fn read(&self, off: usize, dst: &mut [f32]) {
        let n = dst.len();
        match self {
            Buf::F32(v) => dst.copy_from_slice(&v[off..off + n]),
            Buf::F16(v) => HalfFormat::F16.unpack_slice(&v[off..off + n], dst),
            Buf::U8(v) => {
                for (d, &s) in dst.iter_mut().zip(&v[off..off + n]) {
                    *d = u8_decode(s);
                }
            }
        }
    }

    fn bytes(&self) -> usize {
        match self {
            Buf::F32(v) => v.len() * 4,
            Buf::F16(v) => v.len() * 2,
            Buf::U8(v) => v.len(),
        }
    }
}

/// Ring-buffer replay over flat observations (states or flattened
/// images).
pub struct ReplayBuffer {
    capacity: usize,
    obs_dim: usize,
    act_dim: usize,
    obs: Buf,
    next_obs: Buf,
    act: Buf,
    rew: Vec<f32>,
    not_done: Vec<f32>,
    len: usize,
    head: usize,
    /// Shape to give sampled observations (e.g. `[C, H, W]` for pixels;
    /// `[obs_dim]` for states).
    pub obs_shape: Vec<usize>,
}

impl ReplayBuffer {
    pub fn new(capacity: usize, obs_shape: &[usize], act_dim: usize, storage: Storage) -> Self {
        let obs_dim: usize = obs_shape.iter().product();
        // byte packing targets the pixel grid; actions are continuous
        // torques in [-1, 1], so the act rows stay f32 under U8
        let act_storage = match storage {
            Storage::U8 => Storage::F32,
            s => s,
        };
        ReplayBuffer {
            capacity,
            obs_dim,
            act_dim,
            obs: Buf::new(storage, capacity * obs_dim),
            next_obs: Buf::new(storage, capacity * obs_dim),
            act: Buf::new(act_storage, capacity * act_dim),
            rew: vec![0.0; capacity],
            not_done: vec![0.0; capacity],
            len: 0,
            head: 0,
            obs_shape: obs_shape.to_vec(),
        }
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Total storage footprint in bytes (for the memory tables).
    pub fn bytes(&self) -> usize {
        self.obs.bytes() + self.next_obs.bytes() + self.act.bytes() + self.rew.len() * 4 + self.not_done.len() * 4
    }

    /// Append a transition (overwrites the oldest when full).
    pub fn push(&mut self, obs: &[f32], act: &[f32], rew: f32, next_obs: &[f32], done: bool) {
        assert_eq!(obs.len(), self.obs_dim);
        assert_eq!(act.len(), self.act_dim);
        let i = self.head;
        self.obs.write(i * self.obs_dim, obs);
        self.next_obs.write(i * self.obs_dim, next_obs);
        self.act.write(i * self.act_dim, act);
        self.rew[i] = rew;
        self.not_done[i] = if done { 0.0 } else { 1.0 };
        self.head = (self.head + 1) % self.capacity;
        self.len = (self.len + 1).min(self.capacity);
    }

    /// Append `n` transitions from flat row-major chunks (transition `i`
    /// occupies rows `i` of `obs`/`act`/`next_obs` and element `i` of
    /// `rew`/`done`) — the vectorized-producer path: one call per
    /// collect round, equivalent to `n` [`ReplayBuffer::push`] calls in
    /// row order.
    pub fn push_batch(
        &mut self,
        n: usize,
        obs: &[f32],
        act: &[f32],
        rew: &[f32],
        next_obs: &[f32],
        done: &[bool],
    ) {
        assert_eq!(obs.len(), n * self.obs_dim);
        assert_eq!(next_obs.len(), n * self.obs_dim);
        assert_eq!(act.len(), n * self.act_dim);
        assert_eq!(rew.len(), n);
        assert_eq!(done.len(), n);
        for i in 0..n {
            self.push(
                &obs[i * self.obs_dim..(i + 1) * self.obs_dim],
                &act[i * self.act_dim..(i + 1) * self.act_dim],
                rew[i],
                &next_obs[i * self.obs_dim..(i + 1) * self.obs_dim],
                done[i],
            );
        }
    }

    /// Sample a uniform minibatch (allocating convenience wrapper over
    /// [`ReplayBuffer::sample_into`]).
    pub fn sample(&self, batch: usize, rng: &mut Pcg64) -> Batch {
        let mut out = Batch::default();
        self.sample_into(batch, rng, &mut out);
        out
    }

    /// Pre-sample all `count` minibatches of a learner round into the
    /// reusable arena — the allocation-free round path behind
    /// `UpdateSchedule::run_round`. Draws the identical `rng` sequence
    /// as `count` sequential [`ReplayBuffer::sample_into`] /
    /// [`ReplayBuffer::sample_aug_into`] calls (`aug_pad` selects the
    /// DRQ-augmented path), and replay contents are frozen during a
    /// round's update phase in both trainer modes, so sampling up front
    /// is bitwise-neutral for the whole run: the replay stream and the
    /// agent's own noise stream are independent, and pre-sampling only
    /// reorders draws *across* those two streams, never within one.
    pub fn sample_round_into(
        &self,
        count: usize,
        batch: usize,
        aug_pad: Option<usize>,
        rng: &mut Pcg64,
        arena: &mut RoundArena,
    ) {
        if arena.batches.len() < count {
            arena.batches.resize_with(count, Batch::default);
        }
        arena.len = count;
        for out in &mut arena.batches[..count] {
            match aug_pad {
                Some(pad) => self.sample_aug_into(batch, pad, rng, out),
                None => self.sample_into(batch, rng, out),
            }
        }
    }

    /// Allocation-free [`ReplayBuffer::sample`]: draws the identical
    /// index sequence from `rng` and fills the caller-owned batch,
    /// resizing its buffers only when the batch shape changes (i.e. on
    /// first use) — the learner's steady-state path allocates nothing.
    pub fn sample_into(&self, batch: usize, rng: &mut Pcg64, out: &mut Batch) {
        assert!(self.len > 0, "empty replay");
        // the observation tensors want shape [batch] ++ obs_shape; build
        // that list only when the staged batch doesn't already carry it
        let staged = out.obs.shape.len() == self.obs_shape.len() + 1
            && out.obs.shape[0] == batch
            && out.obs.shape[1..] == self.obs_shape[..]
            && out.next_obs.shape == out.obs.shape;
        if !staged {
            // tidy-allow(alloc): batch-shape change only (first use) —
            // the steady-state round path reuses the staged shape
            let mut shape = Vec::with_capacity(self.obs_shape.len() + 1);
            shape.push(batch);
            shape.extend_from_slice(&self.obs_shape);
            out.obs.ensure_shape(&shape);
            out.next_obs.ensure_shape(&shape);
        }
        out.act.ensure_shape(&[batch, self.act_dim]);
        out.rew.resize(batch, 0.0);
        out.not_done.resize(batch, 0.0);
        for b in 0..batch {
            let i = rng.below(self.len);
            self.obs
                .read(i * self.obs_dim, &mut out.obs.data[b * self.obs_dim..(b + 1) * self.obs_dim]);
            self.next_obs.read(
                i * self.obs_dim,
                &mut out.next_obs.data[b * self.obs_dim..(b + 1) * self.obs_dim],
            );
            self.act.read(
                i * self.act_dim,
                &mut out.act.data[b * self.act_dim..(b + 1) * self.act_dim],
            );
            out.rew[b] = self.rew[i];
            out.not_done[b] = self.not_done[i];
        }
    }

    /// Total f32 values currently stored across all transition fields —
    /// the cost model for [`ReplayBuffer::fingerprint`] (callers cap on
    /// it to keep the hash off paper-scale hot paths).
    pub fn stored_floats(&self) -> usize {
        self.len * (2 * self.obs_dim + self.act_dim + 2)
    }

    /// Order-independent multiset hash of every stored transition: each
    /// transition hashes (FNV-1a over its raw f32 bits) independently
    /// and the per-transition hashes are combined with wrapping
    /// addition, so two buffers match iff they hold the same transition
    /// *multiset* — regardless of insertion order or ring position.
    /// This is the observable behind the async trainer's relaxed
    /// determinism contract ("same transitions, any interleave").
    pub fn fingerprint(&self) -> u64 {
        let mut obs = vec![0.0f32; self.obs_dim];
        let mut next = vec![0.0f32; self.obs_dim];
        let mut act = vec![0.0f32; self.act_dim];
        let mut total = 0u64;
        for i in 0..self.len {
            self.obs.read(i * self.obs_dim, &mut obs);
            self.next_obs.read(i * self.obs_dim, &mut next);
            self.act.read(i * self.act_dim, &mut act);
            let mut h = 0xcbf29ce484222325u64; // FNV offset basis
            let mut eat = |v: f32| {
                // tidy-allow(precision): bit pattern feeds the FNV content
                // hash — a checksum, not a numeric conversion.
                for b in v.to_bits().to_le_bytes() {
                    h ^= b as u64;
                    h = h.wrapping_mul(0x100000001b3);
                }
            };
            obs.iter().for_each(|&v| eat(v));
            act.iter().for_each(|&v| eat(v));
            eat(self.rew[i]);
            next.iter().for_each(|&v| eat(v));
            eat(self.not_done[i]);
            total = total.wrapping_add(h);
        }
        total
    }

    /// Serialize the buffer bitwise for a checkpoint: ring metadata plus
    /// only the filled rows `0..len` of every field (until the ring
    /// wraps those are the only live rows; after wrapping `len ==
    /// capacity` and every row is live), in raw storage words — f16
    /// buffers keep their packed u16 form, so no re-quantization happens
    /// on either side of the round trip.
    pub fn ckpt_write(&self, enc: &mut crate::ckpt::Enc) {
        enc.u64(self.capacity as u64);
        enc.u64(self.obs_dim as u64);
        enc.u64(self.act_dim as u64);
        enc.u64(self.len as u64);
        enc.u64(self.head as u64);
        Self::write_buf(enc, &self.obs, self.len * self.obs_dim);
        Self::write_buf(enc, &self.next_obs, self.len * self.obs_dim);
        Self::write_buf(enc, &self.act, self.len * self.act_dim);
        enc.f32s(&self.rew[..self.len]);
        enc.f32s(&self.not_done[..self.len]);
    }

    fn write_buf(enc: &mut crate::ckpt::Enc, buf: &Buf, n: usize) {
        match buf {
            Buf::F32(v) => {
                enc.u8(0);
                enc.f32s(&v[..n]);
            }
            Buf::F16(v) => {
                enc.u8(1);
                enc.u16s(&v[..n]);
            }
            Buf::U8(v) => {
                enc.u8(2);
                enc.u8s(&v[..n]);
            }
        }
    }

    fn read_buf(dec: &mut crate::ckpt::Dec, buf: &mut Buf, n: usize) -> anyhow::Result<()> {
        let tag = dec.u8()?;
        match (tag, buf) {
            (0, Buf::F32(v)) => {
                let xs = dec.f32s()?;
                anyhow::ensure!(xs.len() == n, "replay field holds {} f32s, expected {n}", xs.len());
                v[..n].copy_from_slice(&xs);
            }
            (1, Buf::F16(v)) => {
                let xs = dec.u16s()?;
                anyhow::ensure!(xs.len() == n, "replay field holds {} f16s, expected {n}", xs.len());
                v[..n].copy_from_slice(&xs);
            }
            (2, Buf::U8(v)) => {
                let xs = dec.u8s()?;
                anyhow::ensure!(xs.len() == n, "replay field holds {} u8s, expected {n}", xs.len());
                v[..n].copy_from_slice(&xs);
            }
            (tag, _) => anyhow::bail!(
                "replay storage tag {tag} does not match this run's storage tier"
            ),
        }
        Ok(())
    }

    /// Restore a [`ReplayBuffer::ckpt_write`] snapshot into this
    /// (identically shaped) buffer. Capacity, dims, storage tier, and
    /// every field length are validated before any state is touched by
    /// an unchecked copy — a mismatched or truncated checkpoint is a
    /// typed error, never a panic.
    pub fn ckpt_read(&mut self, dec: &mut crate::ckpt::Dec) -> anyhow::Result<()> {
        let capacity = dec.usize()?;
        let obs_dim = dec.usize()?;
        let act_dim = dec.usize()?;
        anyhow::ensure!(
            capacity == self.capacity && obs_dim == self.obs_dim && act_dim == self.act_dim,
            "replay shape mismatch: checkpoint ({capacity}, {obs_dim}, {act_dim}) vs \
             run ({}, {}, {})",
            self.capacity,
            self.obs_dim,
            self.act_dim
        );
        let len = dec.usize()?;
        anyhow::ensure!(len <= capacity, "replay len {len} exceeds capacity {capacity}");
        let head = dec.usize()?;
        anyhow::ensure!(head < capacity.max(1), "replay head {head} out of range");
        Self::read_buf(dec, &mut self.obs, len * obs_dim)?;
        Self::read_buf(dec, &mut self.next_obs, len * obs_dim)?;
        Self::read_buf(dec, &mut self.act, len * act_dim)?;
        let rew = dec.f32s()?;
        anyhow::ensure!(rew.len() == len, "replay rew holds {} values, expected {len}", rew.len());
        let not_done = dec.f32s()?;
        anyhow::ensure!(
            not_done.len() == len,
            "replay not_done holds {} values, expected {len}",
            not_done.len()
        );
        self.rew[..len].copy_from_slice(&rew);
        self.not_done[..len].copy_from_slice(&not_done);
        self.len = len;
        self.head = head;
        Ok(())
    }

    /// Sample with DRQ random-crop augmentation (allocating wrapper over
    /// [`ReplayBuffer::sample_aug_into`]).
    pub fn sample_aug(&self, batch: usize, pad: usize, rng: &mut Pcg64) -> Batch {
        let mut out = Batch::default();
        self.sample_aug_into(batch, pad, rng, &mut out);
        out
    }

    /// Allocation-free sampling with DRQ random-crop augmentation
    /// (pad-by-`pad` + crop back): requires pixel observations
    /// `[C, H, W]`. The shifts run fully in place (see [`shift_image`]),
    /// so the pixel learner's hot loop allocates nothing.
    pub fn sample_aug_into(&self, batch: usize, pad: usize, rng: &mut Pcg64, out: &mut Batch) {
        self.sample_into(batch, rng, out);
        assert_eq!(self.obs_shape.len(), 3, "augmentation needs [C,H,W] obs");
        let (c, h, w) = (self.obs_shape[0], self.obs_shape[1], self.obs_shape[2]);
        for t in [&mut out.obs, &mut out.next_obs] {
            for bi in 0..batch {
                let dx = rng.below(2 * pad + 1) as isize - pad as isize;
                let dy = rng.below(2 * pad + 1) as isize - pad as isize;
                shift_image(&mut t.data[bi * c * h * w..(bi + 1) * c * h * w], c, h, w, dx, dy);
            }
        }
    }
}

/// Reusable storage for one learner round's pre-sampled minibatches
/// ([`ReplayBuffer::sample_round_into`]). The `Vec<Batch>` grows to the
/// largest round seen (≤ `num_envs` updates) and every batch keeps its
/// tensors, so the steady-state round loop allocates nothing.
#[derive(Default)]
pub struct RoundArena {
    batches: Vec<Batch>,
    len: usize,
}

impl RoundArena {
    /// The round's batches, in sampling order.
    pub fn batches(&self) -> &[Batch] {
        &self.batches[..self.len]
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }
}

/// Shift an image by (dx, dy) with zero padding (equivalent to pad+crop).
///
/// Runs fully in place, row by row: destination rows are visited in the
/// order that keeps every source row unread until it has been copied
/// (bottom-up for downward shifts, top-down for upward), and the
/// horizontal shift within a row is an overlapping `copy_within`
/// (memmove). No scratch copy of the image is made, so DRQ augmentation
/// does not allocate in the learner hot loop.
fn shift_image(img: &mut [f32], c: usize, h: usize, w: usize, dx: isize, dy: isize) {
    if dx == 0 && dy == 0 {
        return;
    }
    if dx.unsigned_abs() >= w || dy.unsigned_abs() >= h {
        img.iter_mut().for_each(|v| *v = 0.0);
        return;
    }
    // horizontal window: dst[dst_x..dst_x+len_x] <- src[src_x..src_x+len_x]
    let (src_x, dst_x, len_x) = if dx >= 0 {
        (0usize, dx as usize, w - dx as usize)
    } else {
        (dx.unsigned_abs(), 0usize, w - dx.unsigned_abs())
    };
    for ch in 0..c {
        let base = ch * h * w;
        for yi in 0..h {
            let y = if dy > 0 { h - 1 - yi } else { yi };
            let sy = y as isize - dy;
            let dst = base + y * w;
            if sy < 0 || sy >= h as isize {
                img[dst..dst + w].iter_mut().for_each(|v| *v = 0.0);
                continue;
            }
            let src = base + sy as usize * w;
            img.copy_within(src + src_x..src + src_x + len_x, dst + dst_x);
            // zero the margin the horizontal shift exposed
            if dx > 0 {
                img[dst..dst + dst_x].iter_mut().for_each(|v| *v = 0.0);
            } else if dx < 0 {
                img[dst + len_x..dst + w].iter_mut().for_each(|v| *v = 0.0);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fill(buf: &mut ReplayBuffer, n: usize) {
        for i in 0..n {
            let v = i as f32;
            buf.push(&[v, v + 0.5], &[0.1 * v], v, &[v + 1.0, v + 1.5], i % 10 == 9);
        }
    }

    #[test]
    fn push_and_sample_roundtrip_f32() {
        let mut buf = ReplayBuffer::new(100, &[2], 1, Storage::F32);
        fill(&mut buf, 50);
        assert_eq!(buf.len(), 50);
        let mut rng = Pcg64::seed(1);
        let b = buf.sample(16, &mut rng);
        assert_eq!(b.obs.shape, vec![16, 2]);
        for r in 0..16 {
            let o = b.obs.row(r)[0];
            assert_eq!(b.obs.row(r)[1], o + 0.5);
            assert_eq!(b.next_obs.row(r)[0], o + 1.0);
            assert_eq!(b.rew[r], o);
        }
    }

    #[test]
    fn ring_overwrites_oldest() {
        let mut buf = ReplayBuffer::new(10, &[2], 1, Storage::F32);
        fill(&mut buf, 25);
        assert_eq!(buf.len(), 10);
        let mut rng = Pcg64::seed(2);
        let b = buf.sample(64, &mut rng);
        // all samples must come from the last 10 pushes (indices 15..25)
        for r in 0..64 {
            assert!(b.rew[r] >= 15.0, "rew={}", b.rew[r]);
        }
    }

    #[test]
    fn f16_storage_halves_bytes_and_quantizes() {
        let mut b32 = ReplayBuffer::new(100, &[4], 2, Storage::F32);
        let mut b16 = ReplayBuffer::new(100, &[4], 2, Storage::F16);
        assert!(b16.bytes() < b32.bytes());
        let obs = [1.0f32, 1e-9, 3.14159, -2.5];
        b16.push(&obs, &[0.5, -0.5], 1.0, &obs, false);
        b32.push(&obs, &[0.5, -0.5], 1.0, &obs, false);
        let mut rng = Pcg64::seed(3);
        let s = b16.sample(1, &mut rng);
        assert_eq!(s.obs.data[0], 1.0);
        assert_eq!(s.obs.data[1], 0.0, "fp16 storage underflows tiny values");
        assert!((s.obs.data[2] - 3.14159).abs() < 2e-3);
    }

    #[test]
    fn u8_storage_quarters_obs_bytes_and_is_exact_on_pixel_grid() {
        let b32 = ReplayBuffer::new(100, &[64], 0, Storage::F32);
        let b8 = ReplayBuffer::new(100, &[64], 0, Storage::U8);
        // obs + next_obs quarter; rew/not_done stay f32
        let fixed = 100 * 4 * 2;
        assert_eq!((b32.bytes() - fixed) / (b8.bytes() - fixed), 4);

        // every value an env can emit (k/255) survives bitwise
        let mut buf = ReplayBuffer::new(8, &[256], 1, Storage::U8);
        let grid: Vec<f32> = (0..=255).map(|k| k as f32 / 255.0).collect();
        buf.push(&grid, &[0.37], 1.0, &grid, false);
        let mut rng = Pcg64::seed(21);
        let s = buf.sample(1, &mut rng);
        for (k, (&got, &want)) in s.obs.data.iter().zip(&grid).enumerate() {
            assert_eq!(got.to_bits(), want.to_bits(), "k={k}");
        }
        // act rows stay f32 under U8: off-grid action survives bitwise
        assert_eq!(s.act.data[0].to_bits(), 0.37f32.to_bits());
    }

    #[test]
    fn u8_storage_quantizes_off_grid_values_within_half_a_step() {
        let mut buf = ReplayBuffer::new(8, &[4], 1, Storage::U8);
        let off = [0.5f32, 0.95, 1e-4, 0.123456];
        buf.push(&off, &[0.0], 0.0, &off, false);
        let mut rng = Pcg64::seed(22);
        let s = buf.sample(1, &mut rng);
        for (&got, &want) in s.obs.data.iter().zip(&off) {
            assert!((got - want).abs() <= 1.0 / 510.0 + 1e-7, "got={got} want={want}");
        }
        // storing a decoded value back is the identity (idempotence): both
        // stored rows now decode to the same grid points bitwise
        let decoded: Vec<f32> = s.obs.data[..4].to_vec();
        buf.push(&decoded, &[0.0], 0.0, &decoded, false);
        let mut r2 = Pcg64::seed(30);
        let again = buf.sample(8, &mut r2);
        for r in 0..8 {
            assert_eq!(again.obs.row(r), &decoded[..], "re-encoding a grid value must be lossless");
        }
    }

    #[test]
    fn ckpt_roundtrip_restores_ring_bitwise() {
        for storage in [Storage::F32, Storage::F16, Storage::U8] {
            // pre-wrap (n < capacity) and post-wrap (n > capacity) fills
            for n in [7usize, 23] {
                let mut buf = ReplayBuffer::new(10, &[2], 1, storage);
                fill(&mut buf, n);
                let mut enc = crate::ckpt::Enc::new();
                buf.ckpt_write(&mut enc);
                let bytes = enc.into_bytes();

                let mut twin = ReplayBuffer::new(10, &[2], 1, storage);
                let mut dec = crate::ckpt::Dec::new(&bytes);
                twin.ckpt_read(&mut dec).unwrap();
                dec.finish().unwrap();
                assert_eq!(twin.len(), buf.len(), "{storage:?} n={n}");
                assert_eq!(twin.fingerprint(), buf.fingerprint(), "{storage:?} n={n}");

                // the ring continues identically: same pushes land in the
                // same slots, same sampling draws bitwise-equal batches
                fill(&mut buf, 4);
                fill(&mut twin, 4);
                assert_eq!(twin.fingerprint(), buf.fingerprint(), "{storage:?} n={n} post-push");
                let b1 = buf.sample(16, &mut Pcg64::seed(9));
                let b2 = twin.sample(16, &mut Pcg64::seed(9));
                for r in 0..16 {
                    assert_eq!(b1.obs.row(r), b2.obs.row(r), "{storage:?} n={n} row {r}");
                    assert_eq!(b1.rew[r].to_bits(), b2.rew[r].to_bits());
                }
            }
        }
    }

    #[test]
    fn ckpt_read_rejects_mismatched_layout() {
        let mut buf = ReplayBuffer::new(10, &[2], 1, Storage::F32);
        fill(&mut buf, 5);
        let mut enc = crate::ckpt::Enc::new();
        buf.ckpt_write(&mut enc);
        let bytes = enc.into_bytes();

        // wrong capacity
        let mut wrong_cap = ReplayBuffer::new(20, &[2], 1, Storage::F32);
        let err = wrong_cap.ckpt_read(&mut crate::ckpt::Dec::new(&bytes)).unwrap_err();
        assert!(err.to_string().contains("shape mismatch"), "{err}");

        // wrong storage tier
        let mut wrong_tier = ReplayBuffer::new(10, &[2], 1, Storage::F16);
        let err = wrong_tier.ckpt_read(&mut crate::ckpt::Dec::new(&bytes)).unwrap_err();
        assert!(err.to_string().contains("storage tag"), "{err}");

        // truncated payload errors instead of panicking
        let mut twin = ReplayBuffer::new(10, &[2], 1, Storage::F32);
        assert!(twin.ckpt_read(&mut crate::ckpt::Dec::new(&bytes[..bytes.len() / 2])).is_err());
    }

    #[test]
    fn shift_image_moves_pixels() {
        let mut img = vec![0.0; 9];
        img[4] = 1.0; // center of 3x3
        shift_image(&mut img, 1, 3, 3, 1, 0);
        assert_eq!(img[5], 1.0);
        assert_eq!(img[4], 0.0);
    }

    /// The original clone-based shift, kept as the test oracle for the
    /// in-place implementation.
    fn shift_image_reference(img: &mut [f32], c: usize, h: usize, w: usize, dx: isize, dy: isize) {
        let orig = img.to_vec();
        img.iter_mut().for_each(|v| *v = 0.0);
        for ch in 0..c {
            for y in 0..h as isize {
                let sy = y - dy;
                if sy < 0 || sy >= h as isize {
                    continue;
                }
                for x in 0..w as isize {
                    let sx = x - dx;
                    if sx < 0 || sx >= w as isize {
                        continue;
                    }
                    img[ch * h * w + y as usize * w + x as usize] =
                        orig[ch * h * w + sy as usize * w + sx as usize];
                }
            }
        }
    }

    #[test]
    fn inplace_shift_matches_clone_reference_for_all_offsets() {
        let (c, h, w) = (2usize, 5usize, 7usize);
        let mut rng = Pcg64::seed(11);
        let base: Vec<f32> = (0..c * h * w).map(|_| rng.uniform_f32()).collect();
        for dy in -6isize..=6 {
            for dx in -8isize..=8 {
                let mut got = base.clone();
                let mut want = base.clone();
                shift_image(&mut got, c, h, w, dx, dy);
                shift_image_reference(&mut want, c, h, w, dx, dy);
                assert_eq!(got, want, "dx={dx} dy={dy}");
            }
        }
    }

    #[test]
    fn push_batch_matches_sequential_push() {
        for storage in [Storage::F32, Storage::F16, Storage::U8] {
            let mut seq = ReplayBuffer::new(7, &[2], 1, storage); // capacity 7: wraps
            let mut bat = ReplayBuffer::new(7, &[2], 1, storage);
            let n = 10usize;
            let obs: Vec<f32> = (0..2 * n).map(|i| i as f32 * 0.25).collect();
            let next: Vec<f32> = (0..2 * n).map(|i| i as f32 * 0.25 + 1.0).collect();
            let act: Vec<f32> = (0..n).map(|i| i as f32 * 0.1 - 0.4).collect();
            let rew: Vec<f32> = (0..n).map(|i| i as f32).collect();
            let done: Vec<bool> = (0..n).map(|i| i % 3 == 0).collect();
            for i in 0..n {
                seq.push(&obs[2 * i..2 * i + 2], &act[i..i + 1], rew[i], &next[2 * i..2 * i + 2], done[i]);
            }
            bat.push_batch(n, &obs, &act, &rew, &next, &done);
            assert_eq!(seq.len(), bat.len());
            let mut r1 = Pcg64::seed(5);
            let mut r2 = Pcg64::seed(5);
            let a = seq.sample(16, &mut r1);
            let b = bat.sample(16, &mut r2);
            assert_eq!(a.obs.data, b.obs.data);
            assert_eq!(a.next_obs.data, b.next_obs.data);
            assert_eq!(a.act.data, b.act.data);
            assert_eq!(a.rew, b.rew);
            assert_eq!(a.not_done, b.not_done);
        }
    }

    #[test]
    fn sample_into_reuses_buffers_and_matches_sample() {
        let mut buf = ReplayBuffer::new(50, &[2], 1, Storage::F16);
        fill(&mut buf, 30);
        let mut r1 = Pcg64::seed(8);
        let mut r2 = Pcg64::seed(8);
        let want = buf.sample(12, &mut r1);
        let mut got = Batch::default();
        buf.sample_into(12, &mut r2, &mut got);
        assert_eq!(want.obs.data, got.obs.data);
        assert_eq!(want.rew, got.rew);
        // second fill into the same batch: no reallocation of the tensor
        // buffers (same shape), identical rng stream continuation
        let ptr = got.obs.data.as_ptr();
        buf.sample_into(12, &mut r2, &mut got);
        assert_eq!(ptr, got.obs.data.as_ptr(), "steady state must not reallocate");
        let again = buf.sample(12, &mut r1);
        assert_eq!(again.obs.data, got.obs.data);
    }

    #[test]
    fn sample_round_into_matches_sequential_sample_into() {
        let mut buf = ReplayBuffer::new(50, &[2], 1, Storage::F16);
        fill(&mut buf, 30);
        let mut r1 = Pcg64::seed(12);
        let mut r2 = Pcg64::seed(12);
        let mut arena = RoundArena::default();
        buf.sample_round_into(4, 8, None, &mut r1, &mut arena);
        assert_eq!(arena.len(), 4);
        for got in arena.batches() {
            let mut want = Batch::default();
            buf.sample_into(8, &mut r2, &mut want);
            assert_eq!(got.obs.data, want.obs.data);
            assert_eq!(got.next_obs.data, want.next_obs.data);
            assert_eq!(got.act.data, want.act.data);
            assert_eq!(got.rew, want.rew);
            assert_eq!(got.not_done, want.not_done);
        }
        // both walked the same rng distance
        assert_eq!(r1.next_u64(), r2.next_u64());
    }

    #[test]
    fn sample_round_into_aug_matches_sequential_and_reuses_buffers() {
        let mut buf = ReplayBuffer::new(20, &[1, 6, 6], 1, Storage::F32);
        let img: Vec<f32> = (0..36).map(|i| i as f32 / 36.0).collect();
        for _ in 0..8 {
            buf.push(&img, &[0.2], 0.5, &img, false);
        }
        let mut r1 = Pcg64::seed(14);
        let mut r2 = Pcg64::seed(14);
        let mut arena = RoundArena::default();
        buf.sample_round_into(3, 5, Some(2), &mut r1, &mut arena);
        for got in arena.batches() {
            let mut want = Batch::default();
            buf.sample_aug_into(5, 2, &mut r2, &mut want);
            assert_eq!(got.obs.data, want.obs.data);
            assert_eq!(got.next_obs.data, want.next_obs.data);
        }
        // steady state: refilling the same round shape must not
        // reallocate any batch tensor, and a SHORTER round must reuse
        // the prefix
        let ptrs: Vec<*const f32> = arena.batches().iter().map(|b| b.obs.data.as_ptr()).collect();
        buf.sample_round_into(3, 5, Some(2), &mut r1, &mut arena);
        let now: Vec<*const f32> = arena.batches().iter().map(|b| b.obs.data.as_ptr()).collect();
        assert_eq!(ptrs, now, "arena must not reallocate in steady state");
        buf.sample_round_into(2, 5, Some(2), &mut r1, &mut arena);
        assert_eq!(arena.len(), 2);
        assert_eq!(arena.batches()[0].obs.data.as_ptr(), ptrs[0]);
    }

    #[test]
    fn sample_aug_into_matches_sample_aug() {
        let mut buf = ReplayBuffer::new(20, &[1, 6, 6], 1, Storage::F32);
        let img: Vec<f32> = (0..36).map(|i| i as f32 / 36.0).collect();
        for _ in 0..8 {
            buf.push(&img, &[0.2], 0.5, &img, false);
        }
        let mut r1 = Pcg64::seed(9);
        let mut r2 = Pcg64::seed(9);
        let want = buf.sample_aug(5, 2, &mut r1);
        let mut got = Batch::default();
        buf.sample_aug_into(5, 2, &mut r2, &mut got);
        assert_eq!(want.obs.data, got.obs.data);
        assert_eq!(want.next_obs.data, got.next_obs.data);
    }

    #[test]
    fn aug_sampling_preserves_shape_and_range() {
        let mut buf = ReplayBuffer::new(20, &[1, 8, 8], 1, Storage::F16);
        let img: Vec<f32> = (0..64).map(|i| i as f32 / 64.0).collect();
        for _ in 0..10 {
            buf.push(&img, &[0.0], 0.0, &img, false);
        }
        let mut rng = Pcg64::seed(4);
        let b = buf.sample_aug(4, 2, &mut rng);
        assert_eq!(b.obs.shape, vec![4, 1, 8, 8]);
        assert!(b.obs.data.iter().all(|&v| (0.0..=1.0).contains(&v)));
    }

    #[test]
    fn fingerprint_is_order_independent_and_content_sensitive() {
        let mk = || ReplayBuffer::new(16, &[2], 1, Storage::F32);
        let t = |i: f32| ([i, i + 0.5], [0.1 * i], i, [i + 1.0, i + 1.5], i as usize % 3 == 0);
        let mut a = mk();
        let mut b = mk();
        for i in 0..6 {
            let (o, ac, r, n, d) = t(i as f32);
            a.push(&o, &ac, r, &n, d);
        }
        for i in (0..6).rev() {
            let (o, ac, r, n, d) = t(i as f32);
            b.push(&o, &ac, r, &n, d);
        }
        assert_eq!(a.fingerprint(), b.fingerprint(), "same multiset, any order");
        let (o, ac, r, n, d) = t(99.0);
        b.push(&o, &ac, r, &n, d);
        assert_ne!(a.fingerprint(), b.fingerprint(), "extra transition must change the hash");
        // empty buffers agree
        assert_eq!(mk().fingerprint(), mk().fingerprint());
    }

    #[test]
    fn not_done_flag() {
        let mut buf = ReplayBuffer::new(10, &[1], 1, Storage::F32);
        buf.push(&[0.0], &[0.0], 0.0, &[0.0], true);
        let mut rng = Pcg64::seed(5);
        let b = buf.sample(4, &mut rng);
        assert!(b.not_done.iter().all(|&v| v == 0.0));
    }
}
