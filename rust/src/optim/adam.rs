//! Adam and **hAdam** (paper §3, method 1, Algorithm 1), with optional
//! **compound loss scaling** (method 5) and **Kahan-gradients**
//! (method 6).
//!
//! The three axes are independent switches so the ablation of Figure 3
//! can flip them one at a time:
//!
//! * [`SecondMoment::Variance`] — classic Adam: `v ← β₂v + (1-β₂)g²`.
//!   In fp16 `g²` underflows for |g| ≲ 2.4e-4, which Figure 6 shows is
//!   *most* gradients.
//! * [`SecondMoment::Hypot`] — hAdam: store `w = √v`, update with the
//!   numerically stable `hypot(√β₂·w, √(1-β₂)·g)`.
//! * `compound`: gradients arrive pre-multiplied by the scale γ (from
//!   scaling the loss); the Adam buffers *keep* the γ factor and the
//!   update uses `m / (w + γε)`, so no unscale pass ever touches the
//!   small gradients. (Plain loss scaling — the Figure 1 baseline — is
//!   the same entry point with `compound = false`: grads are divided by
//!   γ before entering Adam, re-introducing the underflow.)
//! * `kahan_grads`: the parameter update `θ ← θ + Δθ` goes through
//!   compensated summation with a persistent per-parameter compensation
//!   buffer.

use super::scaler::GradScaler;
use crate::lowp::{hypot_stable, Precision};
use crate::nn::pool::{self, SendMut, ThreadPool, ELEMWISE_SPAN};
use crate::nn::Param;
use std::sync::atomic::{AtomicBool, Ordering};

/// Pooled non-finite scan: `true` iff any element is NaN/±∞. The result
/// is a disjunction over disjoint spans, so it is exact and independent
/// of the span schedule; spans short-circuit once the flag is set.
pub(crate) fn slice_has_nonfinite(pool: &ThreadPool, xs: &[f32]) -> bool {
    let found = AtomicBool::new(false);
    pool.run_spans(xs.len(), ELEMWISE_SPAN, |lo, hi| {
        if found.load(Ordering::Relaxed) {
            return;
        }
        if xs[lo..hi].iter().any(|v| !v.is_finite()) {
            found.store(true, Ordering::Relaxed);
        }
    });
    found.load(Ordering::Relaxed)
}

/// Hyperparameters (paper Table 4 defaults).
#[derive(Debug, Clone, Copy)]
pub struct AdamConfig {
    pub lr: f32,
    pub beta1: f32,
    pub beta2: f32,
    pub eps: f32,
}

impl Default for AdamConfig {
    fn default() -> Self {
        AdamConfig { lr: 1e-4, beta1: 0.9, beta2: 0.999, eps: 1e-8 }
    }
}

/// How the second moment is stored and updated.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SecondMoment {
    /// Classic Adam `v` buffer.
    Variance,
    /// hAdam `w = √v` buffer, updated via stable hypot (method 1).
    Hypot,
}

/// How the final `θ += Δθ` is applied.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum UpdateMode {
    /// Plain addition in the working precision.
    Plain,
    /// Kahan-compensated addition (method 6).
    Kahan,
}

/// Adam/hAdam over a fixed list of parameter tensors (state is keyed by
/// position, so always pass the same `params_mut()` ordering).
#[derive(Debug, Clone)]
pub struct Adam {
    pub cfg: AdamConfig,
    pub prec: Precision,
    pub second: SecondMoment,
    pub update: UpdateMode,
    /// Compound scaling (method 5): buffers keep the γ factor.
    pub compound: bool,
    t: u64,
    m: Vec<Vec<f32>>,
    w: Vec<Vec<f32>>, // v (Variance) or √v (Hypot)
    comp: Vec<Vec<f32>>, // Kahan compensation (UpdateMode::Kahan)
    /// Set when the last step was skipped due to non-finite gradients.
    pub last_step_skipped: bool,
}

impl Adam {
    pub fn new(
        cfg: AdamConfig,
        prec: Precision,
        second: SecondMoment,
        update: UpdateMode,
        compound: bool,
    ) -> Self {
        Adam {
            cfg,
            prec,
            second,
            update,
            compound,
            t: 0,
            m: Vec::new(),
            w: Vec::new(),
            comp: Vec::new(),
            last_step_skipped: false,
        }
    }

    /// The paper's full fp16 recipe: hAdam + compound scaling + Kahan.
    pub fn ours_fp16(cfg: AdamConfig) -> Self {
        Adam::new(cfg, Precision::fp16(), SecondMoment::Hypot, UpdateMode::Kahan, true)
    }

    /// fp32 reference Adam.
    pub fn fp32(cfg: AdamConfig) -> Self {
        Adam::new(cfg, Precision::Fp32, SecondMoment::Variance, UpdateMode::Plain, false)
    }

    fn ensure_state(&mut self, params: &[&mut Param]) {
        if self.m.len() == params.len() {
            return;
        }
        assert!(self.m.is_empty(), "parameter list changed size");
        for p in params {
            // tidy-allow(alloc): first-step state init only — the early
            // return above keeps every later step allocation-free
            self.m.push(vec![0.0; p.len()]);
            // tidy-allow(alloc): first-step state init only
            self.w.push(vec![0.0; p.len()]);
            self.comp.push(if self.update == UpdateMode::Kahan {
                // tidy-allow(alloc): first-step state init only
                vec![0.0; p.len()]
            } else {
                // tidy-allow(alloc): capacity-0 placeholder, no heap touch
                Vec::new()
            });
        }
    }

    /// Current step count (bias correction uses `t`).
    pub fn steps(&self) -> u64 {
        self.t
    }

    /// Optimizer-state memory in elements (for the memory tables).
    pub fn state_elems(&self) -> usize {
        self.m.iter().map(Vec::len).sum::<usize>()
            + self.w.iter().map(Vec::len).sum::<usize>()
            + self.comp.iter().map(Vec::len).sum::<usize>()
    }

    /// Serialize the mutable optimizer state bitwise (checkpoint path):
    /// step counter, skip flag, and the m/w/comp moment buffers. The
    /// configuration axes (`cfg`, `prec`, `second`, `update`,
    /// `compound`) are rebuilt from the run config on resume, not
    /// stored.
    pub fn ckpt_write(&self, enc: &mut crate::ckpt::Enc) {
        enc.u64(self.t);
        enc.bool(self.last_step_skipped);
        for field in [&self.m, &self.w, &self.comp] {
            enc.u64(field.len() as u64);
            for v in field {
                enc.f32s(v);
            }
        }
    }

    /// Restore an [`Adam::ckpt_write`] snapshot. If this optimizer's
    /// state is already initialized (a step has run), every buffer shape
    /// is validated first; on a fresh optimizer the buffers are adopted
    /// as-is and `ensure_state` re-checks the tensor count on the next
    /// step.
    pub fn ckpt_read(&mut self, dec: &mut crate::ckpt::Dec) -> anyhow::Result<()> {
        self.t = dec.u64()?;
        self.last_step_skipped = dec.bool()?;
        for (name, field) in
            [("m", &mut self.m), ("w", &mut self.w), ("comp", &mut self.comp)]
        {
            let k = dec.usize()?;
            let mut bufs = Vec::with_capacity(k);
            for _ in 0..k {
                bufs.push(dec.f32s()?);
            }
            if !field.is_empty() {
                anyhow::ensure!(
                    field.len() == k,
                    "adam {name} holds {k} tensors, optimizer expects {}",
                    field.len()
                );
                for (i, (got, want)) in bufs.iter().zip(field.iter()).enumerate() {
                    anyhow::ensure!(
                        got.len() == want.len(),
                        "adam {name}[{i}] holds {} values, optimizer expects {}",
                        got.len(),
                        want.len()
                    );
                }
            }
            *field = bufs;
        }
        Ok(())
    }

    /// One optimizer step.
    ///
    /// `grads` in the params were accumulated from a loss that was
    /// multiplied by `scaler.scale()` (1.0 when no scaling). With
    /// `compound` the scale is *kept* in the buffers; otherwise gradients
    /// are unscaled first (plain loss scaling — this division is where
    /// the Figure 1 baseline re-underflows).
    ///
    /// If any gradient is non-finite the step is skipped and the scaler
    /// backs off, exactly like `torch.cuda.amp`.
    ///
    /// The per-element work fans out over the global worker pool; every
    /// element's result is a pure function of its own index, so the step
    /// is bitwise identical for any thread count (see
    /// [`Adam::step_on`]).
    pub fn step(&mut self, params: &mut [&mut Param], scaler: &mut GradScaler) {
        self.step_on(pool::global(), params, scaler)
    }

    /// [`Adam::step`] over an explicit pool — the seam the
    /// thread-count-invariance tests use (compare a 1-lane pool against
    /// a wide one, bitwise).
    pub fn step_on(&mut self, pool: &ThreadPool, params: &mut [&mut Param], scaler: &mut GradScaler) {
        self.ensure_state(params);
        let p = self.prec;
        let gamma = scaler.scale();

        // amp-style skip on non-finite grads (pooled scan)
        let nonfinite = params.iter().any(|q| slice_has_nonfinite(pool, &q.g));
        scaler.update(nonfinite);
        if nonfinite {
            self.last_step_skipped = true;
            return;
        }
        self.last_step_skipped = false;

        self.t += 1;
        // bias-correction factors, computed in f64 (scalar, free)
        let bc1 = 1.0 - (self.cfg.beta1 as f64).powi(self.t as i32);
        let bc2 = (1.0 - (self.cfg.beta2 as f64).powi(self.t as i32)).sqrt();
        let inv_bc1 = p.q(1.0 / bc1 as f32);
        let inv_bc2 = p.q(1.0 / bc2 as f32);
        let sb2 = p.q(self.cfg.beta2.sqrt());
        let s1mb2 = p.q((1.0 - self.cfg.beta2).sqrt());
        let b1 = self.cfg.beta1;
        let one_m_b1 = p.q(1.0 - b1);
        let beta2 = self.cfg.beta2;
        let lr = self.cfg.lr;
        let (second, update, compound) = (self.second, self.update, self.compound);
        // effective epsilon: compound keeps γ in numerator and
        // denominator, so ε must be scaled by γ to preserve semantics.
        let eps_eff = if self.compound { p.q(self.cfg.eps * gamma) } else { self.cfg.eps };

        for (idx, param) in params.iter_mut().enumerate() {
            let n = param.len();
            let g: &[f32] = &param.g;
            let theta = SendMut::new(param.w.as_mut_ptr());
            let m = SendMut::new(self.m[idx].as_mut_ptr());
            let w = SendMut::new(self.w[idx].as_mut_ptr());
            let comp = SendMut::new(self.comp[idx].as_mut_ptr());
            let fmt = p;
            pool.run_spans(n, ELEMWISE_SPAN, |lo, hi| {
                let len = hi - lo;
                // SAFETY: spans are disjoint, so each task holds the only
                // live views of its `lo..hi` stretch of the buffers.
                let th = unsafe { std::slice::from_raw_parts_mut(theta.get().add(lo), len) };
                let m = unsafe { std::slice::from_raw_parts_mut(m.get().add(lo), len) };
                let w = unsafe { std::slice::from_raw_parts_mut(w.get().add(lo), len) };
                let comp: &mut [f32] = match update {
                    // SAFETY: same disjoint-span contract as the slices above.
                    UpdateMode::Kahan => unsafe {
                        std::slice::from_raw_parts_mut(comp.get().add(lo), len)
                    },
                    UpdateMode::Plain => &mut [],
                };
                let g = &g[lo..hi];
                for i in 0..len {
                    // gradient as Adam sees it
                    let g = if compound || gamma == 1.0 {
                        g[i] // keep the γ factor (compound) or unscaled
                    } else {
                        fmt.q(g[i] / gamma) // plain loss scaling unscale
                    };
                    // first moment
                    m[i] = fmt.q(b1 * m[i] + one_m_b1 * g);
                    // second moment
                    match second {
                        SecondMoment::Variance => {
                            let g2 = fmt.q(g * g);
                            w[i] = fmt.q(beta2 * w[i] + fmt.q((1.0 - beta2) * g2));
                        }
                        SecondMoment::Hypot => {
                            let a = fmt.q(sb2 * w[i]);
                            let b = fmt.q(s1mb2 * g);
                            w[i] = match p {
                                Precision::Fp32 => (a as f64).hypot(b as f64) as f32,
                                Precision::Sim { fmt: f, .. } => hypot_stable(a, b, f),
                            };
                        }
                    }
                    // bias-corrected update
                    let mhat = fmt.q(m[i] * inv_bc1);
                    let denom = match second {
                        SecondMoment::Variance => {
                            let vhat = fmt.q(w[i] * fmt.q(inv_bc2 * inv_bc2));
                            fmt.q(fmt.q(vhat.sqrt()) + eps_eff)
                        }
                        SecondMoment::Hypot => fmt.q(fmt.q(w[i] * inv_bc2) + eps_eff),
                    };
                    let delta = fmt.q(-lr * fmt.q(mhat / denom));
                    // apply
                    match update {
                        UpdateMode::Plain => {
                            th[i] = fmt.q(th[i] + delta);
                        }
                        UpdateMode::Kahan => {
                            let c = &mut comp[i];
                            let y = fmt.q(delta - *c);
                            let t = fmt.q(th[i] + y);
                            *c = fmt.q(fmt.q(t - th[i]) - y);
                            th[i] = t;
                        }
                    }
                }
            });
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lowp::FP16;
    use crate::optim::ScalerConfig;
    use crate::rngs::Pcg64;

    fn quad_grad(p: &mut Param, target: &[f32]) {
        // loss = 0.5*||w - target||²  -> g = w - target
        for i in 0..p.len() {
            p.g[i] = p.w[i] - target[i];
        }
    }

    #[test]
    fn fp32_adam_converges_on_quadratic() {
        let mut p = Param::from_values("w", &[4], vec![5.0, -3.0, 2.0, 0.0]);
        let target = vec![1.0, 1.0, -1.0, 0.5];
        let mut opt = Adam::fp32(AdamConfig { lr: 0.05, ..Default::default() });
        let mut sc = GradScaler::disabled();
        for _ in 0..2000 {
            quad_grad(&mut p, &target);
            opt.step(&mut [&mut p], &mut sc);
        }
        for i in 0..4 {
            assert!((p.w[i] - target[i]).abs() < 1e-2, "w[{i}]={}", p.w[i]);
        }
    }

    #[test]
    fn hadam_equals_adam_in_fp32() {
        // Statement 1 of the paper: in high precision the two coincide.
        let init = vec![2.0f32, -1.0, 0.3];
        let target = vec![0.0f32, 0.0, 0.0];
        let mut pa = Param::from_values("a", &[3], init.clone());
        let mut pb = Param::from_values("b", &[3], init);
        let cfg = AdamConfig { lr: 0.01, ..Default::default() };
        let mut adam = Adam::fp32(cfg);
        let mut hadam = Adam::new(cfg, Precision::Fp32, SecondMoment::Hypot, UpdateMode::Plain, false);
        let mut sc1 = GradScaler::disabled();
        let mut sc2 = GradScaler::disabled();
        for _ in 0..500 {
            quad_grad(&mut pa, &target);
            quad_grad(&mut pb, &target);
            adam.step(&mut [&mut pa], &mut sc1);
            hadam.step(&mut [&mut pb], &mut sc2);
            for i in 0..3 {
                assert!((pa.w[i] - pb.w[i]).abs() < 1e-5, "{} vs {}", pa.w[i], pb.w[i]);
            }
        }
    }

    #[test]
    fn kahan_equals_plain_in_fp32() {
        let init = vec![1.0f32; 8];
        let mut pa = Param::from_values("a", &[8], init.clone());
        let mut pb = Param::from_values("b", &[8], init);
        let cfg = AdamConfig { lr: 0.003, ..Default::default() };
        let mut plain = Adam::fp32(cfg);
        let mut kahan = Adam::new(cfg, Precision::Fp32, SecondMoment::Variance, UpdateMode::Kahan, false);
        let (mut s1, mut s2) = (GradScaler::disabled(), GradScaler::disabled());
        let t = vec![0.0f32; 8];
        for _ in 0..200 {
            quad_grad(&mut pa, &t);
            quad_grad(&mut pb, &t);
            plain.step(&mut [&mut pa], &mut s1);
            kahan.step(&mut [&mut pb], &mut s2);
        }
        for i in 0..8 {
            assert!((pa.w[i] - pb.w[i]).abs() < 1e-5);
        }
    }

    #[test]
    fn naive_fp16_adam_stalls_on_tiny_gradients() {
        // gradients of 1e-5 are representable in fp16 but g² = 1e-10
        // underflows, so naive fp16 Adam's denominator is ~ε and the
        // update explodes relative to hAdam's well-scaled one; worse, m
        // underflows too once (1-β₁)g < 2⁻²⁴. Construct the regime the
        // paper describes: v underflows, hAdam doesn't.
        let cfg = AdamConfig { lr: 1e-4, ..Default::default() };
        let prec = Precision::fp16();
        let mut naive = Adam::new(cfg, prec, SecondMoment::Variance, UpdateMode::Plain, false);
        let mut ours = Adam::new(cfg, prec, SecondMoment::Hypot, UpdateMode::Plain, false);
        let mut pa = Param::from_values("a", &[1], vec![1.0]);
        let mut pb = Param::from_values("b", &[1], vec![1.0]);
        let (mut s1, mut s2) = (GradScaler::disabled(), GradScaler::disabled());
        for _ in 0..100 {
            pa.g[0] = 1e-5;
            pb.g[0] = 1e-5;
            naive.step(&mut [&mut pa], &mut s1);
            ours.step(&mut [&mut pb], &mut s2);
        }
        // v underflowed to 0 for naive -> w buffer stayed 0
        assert_eq!(naive.w[0][0], 0.0, "naive v should underflow");
        assert!(ours.w[0][0] > 0.0, "hAdam w must track √v");
    }

    #[test]
    fn hadam_fp16_matches_fp32_adam_trajectory_closely() {
        // with the full recipe (hAdam + compound + Kahan) an fp16 run of a
        // smooth quadratic should track fp32 Adam to ~1e-2.
        let init: Vec<f32> = vec![2.0, -2.0, 0.7, 1.3];
        let target = vec![0.1f32, -0.4, 0.0, 0.9];
        let cfg = AdamConfig { lr: 0.01, ..Default::default() };
        let mut ref32 = Adam::fp32(cfg);
        let mut ours = Adam::ours_fp16(cfg);
        let mut pa = Param::from_values("a", &[4], init.clone());
        let mut pb = Param::from_values("b", &[4], init);
        pb.quantize(Precision::fp16());
        let mut s1 = GradScaler::disabled();
        let mut s2 = GradScaler::new(ScalerConfig::paper());
        for _ in 0..1500 {
            quad_grad(&mut pa, &target);
            quad_grad(&mut pb, &target);
            // fp16 grads are scaled by γ (loss scaling happens at the loss)
            let g = s2.scale();
            for v in pb.g.iter_mut() {
                *v = FP16.quantize(*v * g);
            }
            ref32.step(&mut [&mut pa], &mut s1);
            ours.step(&mut [&mut pb], &mut s2);
        }
        for i in 0..4 {
            assert!(
                (pa.w[i] - pb.w[i]).abs() < 3e-2,
                "i={i}: fp32={} fp16={}",
                pa.w[i],
                pb.w[i]
            );
        }
    }

    #[test]
    fn skips_step_on_nonfinite_and_backs_off_scale() {
        let cfg = AdamConfig::default();
        let mut opt = Adam::ours_fp16(cfg);
        let mut sc = GradScaler::new(ScalerConfig::paper());
        let s0 = sc.scale();
        let mut p = Param::from_values("a", &[2], vec![1.0, 1.0]);
        p.g = vec![f32::INFINITY, 0.0];
        let w_before = p.w.clone();
        opt.step(&mut [&mut p], &mut sc);
        assert!(opt.last_step_skipped);
        assert_eq!(p.w, w_before);
        assert_eq!(sc.scale(), s0 / 2.0);
        assert_eq!(opt.steps(), 0);
    }

    #[test]
    fn compound_scaling_preserves_adam_semantics_in_fp32() {
        // γ-scaled grads + compound update must equal unscaled Adam
        // exactly in fp32 (paper Appendix C).
        let cfg = AdamConfig { lr: 0.02, ..Default::default() };
        let mut plain = Adam::fp32(cfg);
        let mut comp = Adam::new(cfg, Precision::Fp32, SecondMoment::Variance, UpdateMode::Plain, true);
        let mut pa = Param::from_values("a", &[3], vec![1.0, 2.0, 3.0]);
        let mut pb = Param::from_values("b", &[3], vec![1.0, 2.0, 3.0]);
        let mut s1 = GradScaler::disabled();
        let mut s2 = GradScaler::fixed(1024.0);
        let t = vec![0.0f32; 3];
        let mut rng = Pcg64::seed(1);
        for _ in 0..100 {
            quad_grad(&mut pa, &t);
            quad_grad(&mut pb, &t);
            let noise: Vec<f32> = (0..3).map(|_| rng.normal_f32() * 0.01).collect();
            for i in 0..3 {
                pa.g[i] += noise[i];
                pb.g[i] = (pb.g[i] + noise[i]) * 1024.0;
            }
            plain.step(&mut [&mut pa], &mut s1);
            comp.step(&mut [&mut pb], &mut s2);
            for i in 0..3 {
                let d = (pa.w[i] - pb.w[i]).abs();
                assert!(d < 1e-4, "i={i} d={d}");
            }
        }
    }

    #[test]
    fn compound_scaling_saves_small_gradients_in_fp16() {
        // g = 1e-8 underflows to 0 in fp16 (below half the smallest
        // subnormal 2.98e-8) — the gradient is simply invisible to a bare
        // fp16 optimizer. With compound scaling at γ=1e4 the loss (and so
        // the gradient) is scaled before rounding: 1e-4 stays alive and
        // the buffers keep the γ factor.
        let cfg = AdamConfig { lr: 1e-3, ..Default::default() };
        let prec = Precision::fp16();
        let mut bare = Adam::new(cfg, prec, SecondMoment::Hypot, UpdateMode::Plain, false);
        let mut comp = Adam::new(cfg, prec, SecondMoment::Hypot, UpdateMode::Plain, true);
        let mut pa = Param::from_values("a", &[1], vec![1.0]);
        let mut pb = Param::from_values("b", &[1], vec![1.0]);
        let mut s1 = GradScaler::disabled();
        let mut s2 = GradScaler::fixed(1e4);
        for _ in 0..50 {
            pa.g[0] = FP16.quantize(1e-8);
            pb.g[0] = FP16.quantize(1e-8 * 1e4);
            bare.step(&mut [&mut pa], &mut s1);
            comp.step(&mut [&mut pb], &mut s2);
        }
        // The bare run either sees a zero gradient (no movement) or —
        // even worse, and exactly what the paper warns about — divides
        // 0/0 because Adam's ε=1e-8 itself underflows in fp16, yielding
        // NaN parameters. Either way it makes no progress.
        assert!(
            pa.w[0] == 1.0 || pa.w[0].is_nan(),
            "bare fp16 must fail, got {}",
            pa.w[0]
        );
        assert!(pb.w[0] < 1.0, "compound-scaled run must make progress");
        assert!(pb.w[0].is_finite());
    }

    #[test]
    fn pooled_step_is_thread_count_invariant() {
        // a parameter long enough to span several claim units; every
        // optimizer flavour must produce bitwise-identical weights and
        // buffers on a 1-lane pool (serial inline) and wide pools
        use crate::nn::pool::{ThreadPool, ELEMWISE_SPAN};
        let n = 3 * ELEMWISE_SPAN + 17;
        let mut rng = Pcg64::seed(33);
        let init: Vec<f32> = (0..n).map(|_| rng.normal_f32()).collect();
        let grads: Vec<Vec<f32>> =
            (0..5).map(|_| (0..n).map(|_| rng.normal_f32() * 1e-3).collect()).collect();
        let cfg = AdamConfig { lr: 0.01, ..Default::default() };
        let cases: [(Precision, SecondMoment, UpdateMode, bool); 4] = [
            (Precision::Fp32, SecondMoment::Variance, UpdateMode::Plain, false),
            (Precision::Fp32, SecondMoment::Hypot, UpdateMode::Kahan, false),
            (Precision::fp16(), SecondMoment::Hypot, UpdateMode::Kahan, true),
            (Precision::fp16(), SecondMoment::Variance, UpdateMode::Plain, false),
        ];
        for (prec, second, update, compound) in cases {
            let run = |threads: usize| -> (Vec<f32>, Vec<f32>, Vec<f32>) {
                let pool = ThreadPool::new(threads);
                let mut opt = Adam::new(cfg, prec, second, update, compound);
                let mut sc =
                    if compound { GradScaler::fixed(1024.0) } else { GradScaler::disabled() };
                let mut p = Param::from_values("p", &[n], init.clone());
                for g in &grads {
                    p.g.copy_from_slice(g);
                    if compound {
                        for v in p.g.iter_mut() {
                            *v *= 1024.0;
                        }
                    }
                    opt.step_on(&pool, &mut [&mut p], &mut sc);
                }
                (p.w, opt.m[0].clone(), opt.w[0].clone())
            };
            let want = run(1);
            for threads in [2usize, 8] {
                let got = run(threads);
                assert!(
                    got.0.iter().zip(&want.0).all(|(a, b)| a.to_bits() == b.to_bits()),
                    "weights differ: {prec:?} {second:?} {update:?} threads={threads}"
                );
                assert!(
                    got.1.iter().zip(&want.1).all(|(a, b)| a.to_bits() == b.to_bits()),
                    "m buffer differs: threads={threads}"
                );
                assert!(
                    got.2.iter().zip(&want.2).all(|(a, b)| a.to_bits() == b.to_bits()),
                    "v/w buffer differs: threads={threads}"
                );
            }
        }
    }

    #[test]
    fn pooled_nonfinite_scan_still_skips_and_backs_off() {
        use crate::nn::pool::{ThreadPool, ELEMWISE_SPAN};
        let n = 2 * ELEMWISE_SPAN + 5;
        let pool = ThreadPool::new(4);
        let mut opt = Adam::ours_fp16(AdamConfig::default());
        let mut sc = GradScaler::new(ScalerConfig::paper());
        let s0 = sc.scale();
        let mut p = Param::from_values("a", &[n], vec![1.0; n]);
        p.g = vec![1e-3; n];
        p.g[n - 1] = f32::NAN; // non-finite in the LAST span
        let w_before = p.w.clone();
        opt.step_on(&pool, &mut [&mut p], &mut sc);
        assert!(opt.last_step_skipped);
        assert_eq!(p.w, w_before);
        assert_eq!(sc.scale(), s0 / 2.0);
    }

    #[test]
    fn ckpt_roundtrip_continues_bitwise() {
        // step, checkpoint, restore into a freshly-constructed optimizer,
        // then both must walk the identical trajectory bit for bit
        let cfg = AdamConfig { lr: 0.01, ..Default::default() };
        let mut rng = Pcg64::seed(77);
        let init: Vec<f32> = (0..40).map(|_| rng.normal_f32()).collect();
        let mut opt = Adam::ours_fp16(cfg);
        let mut sc = GradScaler::new(ScalerConfig::paper());
        let mut p = Param::from_values("p", &[40], init.clone());
        for _ in 0..5 {
            for (i, g) in p.g.iter_mut().enumerate() {
                *g = (i as f32 - 20.0) * 1e-3 * sc.scale();
            }
            opt.step(&mut [&mut p], &mut sc);
        }
        let mut enc = crate::ckpt::Enc::new();
        opt.ckpt_write(&mut enc);
        let bytes = enc.into_bytes();

        let mut twin = Adam::ours_fp16(cfg);
        let mut dec = crate::ckpt::Dec::new(&bytes);
        twin.ckpt_read(&mut dec).unwrap();
        dec.finish().unwrap();
        assert_eq!(twin.steps(), opt.steps());

        let mut q = Param::from_values("q", &[40], p.w.clone());
        let mut sc2 = sc.clone();
        for _ in 0..5 {
            for (i, g) in p.g.iter_mut().enumerate() {
                *g = (i as f32 - 7.0) * 2e-3 * sc.scale();
            }
            q.g.copy_from_slice(&p.g);
            opt.step(&mut [&mut p], &mut sc);
            twin.step(&mut [&mut q], &mut sc2);
        }
        assert!(p.w.iter().zip(&q.w).all(|(a, b)| a.to_bits() == b.to_bits()));

        // mismatched buffer shapes are a typed error once state exists
        let mut wrong = Adam::ours_fp16(cfg);
        let mut sw = GradScaler::disabled();
        let mut small = Param::from_values("s", &[3], vec![1.0; 3]);
        small.g = vec![1e-3; 3];
        wrong.step(&mut [&mut small], &mut sw);
        let err = wrong.ckpt_read(&mut crate::ckpt::Dec::new(&bytes)).unwrap_err();
        assert!(err.to_string().contains("optimizer expects"), "{err}");
    }

    #[test]
    fn state_elems_counts_kahan_buffers() {
        let cfg = AdamConfig::default();
        let mut a = Adam::ours_fp16(cfg);
        let mut sc = GradScaler::disabled();
        let mut p = Param::from_values("a", &[10], vec![0.0; 10]);
        p.g = vec![1e-3; 10];
        a.step(&mut [&mut p], &mut sc);
        assert_eq!(a.state_elems(), 30); // m + w + comp
    }
}
