//! **Kahan-momentum** (paper §3, method 4): the target network's soft
//! update `ψ̂ ← ψ̂ + τ(ψ - ψ̂)` via compensated summation, on a buffer
//! scaled by a constant `C > 1` so the increment `C·τ·(ψ - ψ̂)` clears
//! the subnormal range (paper Appendix B: `C = 1e4` for states, `100`
//! for pixels).

use crate::lowp::Precision;
use crate::nn::pool::{self, SendMut, ThreadPool, ELEMWISE_SPAN};

/// Scaled, Kahan-compensated exponential moving average of a parameter
/// vector — the target network's weights.
#[derive(Debug, Clone)]
pub struct ScaledKahanEma {
    /// Scaled accumulator: `C · ψ̂`.
    buf: Vec<f32>,
    comp: Vec<f32>,
    /// Unscaled view `ψ̂` refreshed after every update (what forward
    /// passes read).
    view: Vec<f32>,
    pub c: f32,
    pub prec: Precision,
    /// When false, fall back to plain (uncompensated, unscaled) EMA —
    /// the ablation baseline.
    pub compensated: bool,
}

impl ScaledKahanEma {
    pub fn new(init: &[f32], c: f32, prec: Precision, compensated: bool) -> Self {
        let mut buf: Vec<f32> = init.iter().map(|&v| prec.q(v * c)).collect();
        if !compensated {
            buf = init.to_vec();
            prec.q_slice(&mut buf);
        }
        let mut view = init.to_vec();
        prec.q_slice(&mut view);
        ScaledKahanEma { comp: vec![0.0; init.len()], buf, view, c, prec, compensated }
    }

    /// The current target weights `ψ̂`.
    #[inline]
    pub fn weights(&self) -> &[f32] {
        &self.view
    }

    /// Number of tracked weights.
    pub fn len(&self) -> usize {
        self.buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    /// Soft update toward `psi` with rate `tau` (= 1-β in the paper's
    /// notation), all arithmetic in the working precision. Fans the
    /// per-element work over the global pool; every element is
    /// independent, so the result is bitwise thread-count-invariant.
    pub fn update(&mut self, psi: &[f32], tau: f32) {
        assert_eq!(psi.len(), self.buf.len());
        self.update_span_on(pool::global(), 0, psi, tau)
    }

    /// Update the `offset..offset + psi.len()` stretch of the tracked
    /// vector toward `psi`. Walking a parameter list span by span is
    /// bitwise identical to one flat [`ScaledKahanEma::update`] call
    /// (elements are independent) — the entry point that lets the
    /// target-network sync read ψ straight out of per-layer parameter
    /// slices instead of a flattened copy.
    pub fn update_span(&mut self, offset: usize, psi: &[f32], tau: f32) {
        self.update_span_on(pool::global(), offset, psi, tau)
    }

    /// [`ScaledKahanEma::update_span`] over an explicit pool (the seam
    /// the thread-count-invariance tests pin).
    pub fn update_span_on(&mut self, pool: &ThreadPool, offset: usize, psi: &[f32], tau: f32) {
        assert!(offset + psi.len() <= self.buf.len(), "span out of range");
        let p = self.prec;
        let n = psi.len();
        let buf = SendMut::new(self.buf[offset..].as_mut_ptr());
        let view = SendMut::new(self.view[offset..].as_mut_ptr());
        if !self.compensated {
            pool.run_spans(n, ELEMWISE_SPAN, |lo, hi| {
                let len = hi - lo;
                // SAFETY: spans are disjoint — each task owns its stretch.
                let buf = unsafe { std::slice::from_raw_parts_mut(buf.get().add(lo), len) };
                let view = unsafe { std::slice::from_raw_parts_mut(view.get().add(lo), len) };
                let psi = &psi[lo..hi];
                for i in 0..len {
                    let d = p.q(tau * p.q(psi[i] - buf[i]));
                    buf[i] = p.q(buf[i] + d);
                    view[i] = buf[i];
                }
            });
            return;
        }
        let c = self.c;
        let inv_c = p.q(1.0 / c);
        // multiply C·τ *first*: (C·τ)·(ψ-ψ̂) keeps the tiny difference out
        // of the subnormal range, which is the whole point of the scale.
        let ct = p.q(c * tau);
        let comp = SendMut::new(self.comp[offset..].as_mut_ptr());
        pool.run_spans(n, ELEMWISE_SPAN, |lo, hi| {
            let len = hi - lo;
            // SAFETY: spans are disjoint — each task owns its stretch.
            let buf = unsafe { std::slice::from_raw_parts_mut(buf.get().add(lo), len) };
            let view = unsafe { std::slice::from_raw_parts_mut(view.get().add(lo), len) };
            let comp = unsafe { std::slice::from_raw_parts_mut(comp.get().add(lo), len) };
            let psi = &psi[lo..hi];
            for i in 0..len {
                // increment on the scaled buffer: (C·τ)·(ψ - ψ̂)
                let hat = view[i];
                let delta = p.q(ct * p.q(psi[i] - hat));
                // Kahan add into buf
                let y = p.q(delta - comp[i]);
                let t = p.q(buf[i] + y);
                comp[i] = p.q(p.q(t - buf[i]) - y);
                buf[i] = t;
                view[i] = p.q(buf[i] * inv_c);
            }
        });
    }

    /// Memory elements used (buffer + compensation + view).
    pub fn state_elems(&self) -> usize {
        self.buf.len() + self.comp.len() + self.view.len()
    }

    /// Serialize the accumulator state bitwise (checkpoint path): the
    /// scaled buffer, the Kahan compensation, and the unscaled view.
    /// `c`/`prec`/`compensated` are rebuilt from the run config.
    pub fn ckpt_write(&self, enc: &mut crate::ckpt::Enc) {
        enc.f32s(&self.buf);
        enc.f32s(&self.comp);
        enc.f32s(&self.view);
    }

    /// Restore a [`ScaledKahanEma::ckpt_write`] snapshot; every buffer
    /// length is validated against this accumulator's size.
    pub fn ckpt_read(&mut self, dec: &mut crate::ckpt::Dec) -> anyhow::Result<()> {
        dec.f32s_into(&mut self.buf)?;
        dec.f32s_into(&mut self.comp)?;
        dec.f32s_into(&mut self.view)?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lowp::FP16;

    #[test]
    fn fp32_matches_plain_ema() {
        let psi = vec![1.0f32, -2.0, 0.5];
        let mut k = ScaledKahanEma::new(&[0.0, 0.0, 0.0], 1e4, Precision::Fp32, true);
        let mut plain = vec![0.0f32; 3];
        let tau = 0.005;
        for _ in 0..1000 {
            k.update(&psi, tau);
            for i in 0..3 {
                plain[i] += tau * (psi[i] - plain[i]);
            }
        }
        for i in 0..3 {
            assert!((k.weights()[i] - plain[i]).abs() < 1e-4, "i={i}");
        }
    }

    #[test]
    fn fp16_kahan_ema_tracks_where_plain_stalls() {
        // paper setting: τ=0.005, weights O(1). τ·Δ ≈ 5e-3·Δ; once
        // |Δ| < ~0.1 the increment on a weight of magnitude 1 is below
        // half-ulp (ulp(1)≈1e-3) and plain fp16 EMA freezes; Kahan+scale
        // keeps integrating.
        let psi = vec![1.0f32; 32];
        let tau = 0.005f32;
        let prec = Precision::fp16();
        let mut kahan = ScaledKahanEma::new(&vec![0.9f32; 32], 1e4, prec, true);
        let mut plain = ScaledKahanEma::new(&vec![0.9f32; 32], 1e4, prec, false);
        for _ in 0..5000 {
            kahan.update(&psi, tau);
            plain.update(&psi, tau);
        }
        let k_err = (kahan.weights()[0] - 1.0).abs();
        let p_err = (plain.weights()[0] - 1.0).abs();
        assert!(k_err < 5e-3, "kahan err {k_err}");
        assert!(p_err > 5.0 * k_err.max(1e-4), "plain err {p_err} vs kahan {k_err}");
    }

    #[test]
    fn scaled_buffer_avoids_subnormal_increments() {
        // increment τ(ψ-ψ̂) ≈ 5e-8 is below fp16's min subnormal; scaled
        // by C=1e4 it is 5e-4 — representable.
        let tau = 0.005f32;
        let psi = vec![1e-5f32];
        let prec = Precision::fp16();
        let mut k = ScaledKahanEma::new(&[0.0], 1e4, prec, true);
        for _ in 0..2000 {
            k.update(&psi, tau);
        }
        let got = k.weights()[0];
        assert!(
            (got - 1e-5).abs() < 2e-6,
            "scaled Kahan EMA should converge to 1e-5, got {got}"
        );
        // sanity: near convergence the *unscaled* increment τ·(ψ-ψ̂) is
        // one subnormal step times τ — far below fp16's resolution.
        assert_eq!(FP16.quantize(tau * FP16.min_subnormal()), 0.0);
    }

    #[test]
    fn pooled_update_is_thread_count_invariant() {
        use crate::nn::pool::{ThreadPool, ELEMWISE_SPAN};
        let n = 2 * ELEMWISE_SPAN + 33;
        let mut rng = crate::rngs::Pcg64::seed(51);
        let init: Vec<f32> = (0..n).map(|_| rng.normal_f32()).collect();
        let psi: Vec<f32> = (0..n).map(|_| rng.normal_f32()).collect();
        for (prec, comp) in [(Precision::Fp32, true), (Precision::fp16(), true), (Precision::fp16(), false)] {
            let run = |threads: usize| -> Vec<f32> {
                let pool = ThreadPool::new(threads);
                let mut ema = ScaledKahanEma::new(&init, 1e4, prec, comp);
                for _ in 0..20 {
                    ema.update_span_on(&pool, 0, &psi, 0.005);
                }
                ema.weights().to_vec()
            };
            let want = run(1);
            for threads in [2usize, 8] {
                let got = run(threads);
                assert!(
                    got.iter().zip(&want).all(|(a, b)| a.to_bits() == b.to_bits()),
                    "prec={prec:?} comp={comp} threads={threads}"
                );
            }
        }
    }

    #[test]
    fn update_span_walk_matches_flat_update() {
        // walking the vector in per-layer spans (the in-place target
        // sync) must equal one flat update call, bitwise
        let n = 300usize;
        let mut rng = crate::rngs::Pcg64::seed(52);
        let init: Vec<f32> = (0..n).map(|_| rng.normal_f32()).collect();
        let psi: Vec<f32> = (0..n).map(|_| rng.normal_f32()).collect();
        let prec = Precision::fp16();
        let mut flat = ScaledKahanEma::new(&init, 1e4, prec, true);
        let mut spans = ScaledKahanEma::new(&init, 1e4, prec, true);
        let cuts = [0usize, 7, 130, 131, 300];
        for _ in 0..50 {
            flat.update(&psi, 0.005);
            for w in cuts.windows(2) {
                spans.update_span(w[0], &psi[w[0]..w[1]], 0.005);
            }
        }
        assert!(flat
            .weights()
            .iter()
            .zip(spans.weights())
            .all(|(a, b)| a.to_bits() == b.to_bits()));
    }

    #[test]
    fn ckpt_roundtrip_continues_bitwise() {
        let mut rng = crate::rngs::Pcg64::seed(61);
        let init: Vec<f32> = (0..50).map(|_| rng.normal_f32()).collect();
        let psi: Vec<f32> = (0..50).map(|_| rng.normal_f32()).collect();
        let prec = Precision::fp16();
        let mut ema = ScaledKahanEma::new(&init, 1e4, prec, true);
        for _ in 0..30 {
            ema.update(&psi, 0.005);
        }
        let mut enc = crate::ckpt::Enc::new();
        ema.ckpt_write(&mut enc);
        let bytes = enc.into_bytes();

        let mut twin = ScaledKahanEma::new(&init, 1e4, prec, true);
        let mut dec = crate::ckpt::Dec::new(&bytes);
        twin.ckpt_read(&mut dec).unwrap();
        dec.finish().unwrap();
        for _ in 0..30 {
            ema.update(&psi, 0.005);
            twin.update(&psi, 0.005);
        }
        assert!(ema
            .weights()
            .iter()
            .zip(twin.weights())
            .all(|(a, b)| a.to_bits() == b.to_bits()));

        // wrong-size accumulator rejects the payload instead of panicking
        let mut wrong = ScaledKahanEma::new(&init[..10], 1e4, prec, true);
        assert!(wrong.ckpt_read(&mut crate::ckpt::Dec::new(&bytes)).is_err());
    }

    #[test]
    fn state_elems() {
        let k = ScaledKahanEma::new(&[0.0; 10], 1e4, Precision::fp16(), true);
        assert_eq!(k.state_elems(), 30);
    }
}
