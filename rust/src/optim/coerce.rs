//! Numeric coercion — the "coerc" baseline of the paper's Figure 1:
//! NaN → 0, ±∞ → ± the format's largest finite value.

/// Coerce non-finite values in place: NaN → 0, ±∞ → ±`max_value`.
/// Returns the number of values touched (for telemetry).
pub fn coerce_nonfinite(xs: &mut [f32], max_value: f32) -> usize {
    let mut n = 0;
    for v in xs.iter_mut() {
        if v.is_nan() {
            *v = 0.0;
            n += 1;
        } else if v.is_infinite() {
            *v = max_value.copysign(*v);
            n += 1;
        }
    }
    n
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn coerces_all_nonfinite() {
        let mut xs = vec![1.0, f32::NAN, f32::INFINITY, f32::NEG_INFINITY, -2.0];
        let n = coerce_nonfinite(&mut xs, 65504.0);
        assert_eq!(n, 3);
        assert_eq!(xs, vec![1.0, 0.0, 65504.0, -65504.0, -2.0]);
    }

    #[test]
    fn finite_values_untouched() {
        let mut xs = vec![0.0, -0.0, 1e-30, 3.4e38];
        let n = coerce_nonfinite(&mut xs, 65504.0);
        assert_eq!(n, 0);
        assert_eq!(xs, vec![0.0, -0.0, 1e-30, 3.4e38]);
    }
}
