//! Numeric coercion — the "coerc" baseline of the paper's Figure 1:
//! NaN → 0, ±∞ → ± the format's largest finite value.

use crate::nn::pool::{self, SendMut, ThreadPool, ELEMWISE_SPAN};
use std::sync::atomic::{AtomicUsize, Ordering};

/// Coerce non-finite values in place: NaN → 0, ±∞ → ±`max_value`.
/// Returns the number of values touched (for telemetry). Large slices
/// fan out over the global pool; the per-element rewrite and the touch
/// count are both independent of how elements are batched onto workers,
/// so results are identical to the serial loop.
pub fn coerce_nonfinite(xs: &mut [f32], max_value: f32) -> usize {
    coerce_nonfinite_on(pool::global(), xs, max_value)
}

/// [`coerce_nonfinite`] over an explicit pool (the seam the
/// thread-count-invariance tests pin).
pub fn coerce_nonfinite_on(pool: &ThreadPool, xs: &mut [f32], max_value: f32) -> usize {
    let total = AtomicUsize::new(0);
    let ptr = SendMut::new(xs.as_mut_ptr());
    pool.run_spans(xs.len(), ELEMWISE_SPAN, |lo, hi| {
        // SAFETY: spans are disjoint — each task owns its stretch.
        let span = unsafe { std::slice::from_raw_parts_mut(ptr.get().add(lo), hi - lo) };
        let mut n = 0;
        for v in span.iter_mut() {
            if v.is_nan() {
                *v = 0.0;
                n += 1;
            } else if v.is_infinite() {
                *v = max_value.copysign(*v);
                n += 1;
            }
        }
        if n > 0 {
            total.fetch_add(n, Ordering::Relaxed);
        }
    });
    total.into_inner()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::pool::ThreadPool;

    #[test]
    fn coerces_all_nonfinite() {
        let mut xs = vec![1.0, f32::NAN, f32::INFINITY, f32::NEG_INFINITY, -2.0];
        let n = coerce_nonfinite(&mut xs, 65504.0);
        assert_eq!(n, 3);
        assert_eq!(xs, vec![1.0, 0.0, 65504.0, -65504.0, -2.0]);
    }

    #[test]
    fn finite_values_untouched() {
        let mut xs = vec![0.0, -0.0, 1e-30, 3.4e38];
        let n = coerce_nonfinite(&mut xs, 65504.0);
        assert_eq!(n, 0);
        assert_eq!(xs, vec![0.0, -0.0, 1e-30, 3.4e38]);
    }

    #[test]
    fn pooled_coercion_matches_serial_for_any_pool_size() {
        // large buffer spanning several claim units, non-finite values
        // sprinkled at deterministic positions
        let n = 3 * ELEMWISE_SPAN + 17;
        let base: Vec<f32> = (0..n)
            .map(|i| match i % 1013 {
                0 => f32::NAN,
                1 => f32::INFINITY,
                2 => f32::NEG_INFINITY,
                k => k as f32 * 0.5 - 100.0,
            })
            .collect();
        let serial_pool = ThreadPool::new(1);
        let mut want = base.clone();
        let want_n = coerce_nonfinite_on(&serial_pool, &mut want, 65504.0);
        for threads in [2usize, 8] {
            let pool = ThreadPool::new(threads);
            let mut got = base.clone();
            let got_n = coerce_nonfinite_on(&pool, &mut got, 65504.0);
            assert_eq!(got_n, want_n, "threads={threads}");
            assert!(
                got.iter().zip(&want).all(|(a, b)| a.to_bits() == b.to_bits()),
                "threads={threads}"
            );
        }
    }
}
