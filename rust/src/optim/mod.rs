//! Optimizers and numeric-stability machinery — the paper's methods
//! **1 (hAdam)**, **4 (Kahan-momentum)**, **5 (compound loss scaling)**
//! and **6 (Kahan-gradients)** live here, together with the
//! supervised-learning baselines of Figure 1 (plain loss scaling, mixed
//! precision, numeric coercion).
//!
//! All optimizer arithmetic is routed through a
//! [`crate::lowp::Precision`] so the same code runs the fp32 reference,
//! genuine fp16 state, and the Figure-4 e5mX sweep.

mod adam;
mod coerce;
mod kahan_ema;
mod scaler;

pub use adam::{Adam, AdamConfig, SecondMoment, UpdateMode};
pub use coerce::coerce_nonfinite;
pub use kahan_ema::ScaledKahanEma;
pub use scaler::{GradScaler, ScalerConfig};
