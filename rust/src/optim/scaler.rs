//! Dynamic gradient scaler — the PyTorch-amp schedule the paper follows
//! (Appendix B): start at `init_scale`; on any non-finite gradient halve
//! the scale and skip the step; after `growth_interval` consecutive clean
//! steps double it.
//!
//! Used identically by (a) the plain loss-scaling baseline of Figure 1,
//! (b) the mixed-precision baseline, and (c) the paper's compound loss
//! scaling — the difference between them is what the *optimizer* does
//! with the scaled gradients, not the schedule.

/// Scaler schedule parameters.
#[derive(Debug, Clone, Copy)]
pub struct ScalerConfig {
    pub init_scale: f32,
    pub growth_interval: u64,
    pub growth_factor: f32,
    pub backoff_factor: f32,
    pub max_scale: f32,
}

impl ScalerConfig {
    /// The paper's settings (Table 5): init 1e4, growth interval 1e4.
    pub fn paper() -> Self {
        ScalerConfig {
            init_scale: 1e4,
            growth_interval: 10_000,
            growth_factor: 2.0,
            backoff_factor: 0.5,
            max_scale: 1e8,
        }
    }

    /// torch.cuda.amp defaults (Appendix E "amp" baseline): 2¹⁶ / 2000.
    pub fn amp_default() -> Self {
        ScalerConfig {
            init_scale: 65536.0,
            growth_interval: 2000,
            growth_factor: 2.0,
            backoff_factor: 0.5,
            max_scale: 1e8,
        }
    }
}

/// Dynamic loss/gradient scaler.
#[derive(Debug, Clone)]
pub struct GradScaler {
    scale: f32,
    cfg: ScalerConfig,
    good_steps: u64,
    enabled: bool,
    /// Number of skipped (non-finite) steps, for telemetry.
    pub skipped: u64,
}

impl GradScaler {
    pub fn new(cfg: ScalerConfig) -> Self {
        GradScaler { scale: cfg.init_scale, cfg, good_steps: 0, enabled: true, skipped: 0 }
    }

    /// No scaling at all (fp32 runs): scale() == 1 and update() never
    /// changes it.
    pub fn disabled() -> Self {
        let cfg = ScalerConfig { init_scale: 1.0, ..ScalerConfig::paper() };
        GradScaler { scale: 1.0, cfg, good_steps: 0, enabled: false, skipped: 0 }
    }

    /// Fixed scale γ (no dynamics) — used by unit tests and the
    /// Kahan-momentum buffer scale.
    pub fn fixed(scale: f32) -> Self {
        let cfg = ScalerConfig { init_scale: scale, ..ScalerConfig::paper() };
        GradScaler { scale, cfg, good_steps: 0, enabled: false, skipped: 0 }
    }

    /// Current multiplier to apply to the loss (and hence gradients).
    #[inline]
    pub fn scale(&self) -> f32 {
        self.scale
    }

    /// Serialize the scaler dynamics bitwise (checkpoint path): current
    /// scale, clean-step streak, and skip counter. The schedule (`cfg`)
    /// and the `enabled` switch are rebuilt from the run config.
    pub fn ckpt_write(&self, enc: &mut crate::ckpt::Enc) {
        enc.f32(self.scale);
        enc.u64(self.good_steps);
        enc.u64(self.skipped);
    }

    /// Restore a [`GradScaler::ckpt_write`] snapshot.
    pub fn ckpt_read(&mut self, dec: &mut crate::ckpt::Dec) -> anyhow::Result<()> {
        self.scale = dec.f32()?;
        self.good_steps = dec.u64()?;
        self.skipped = dec.u64()?;
        Ok(())
    }

    /// Record the outcome of a step: `nonfinite = true` halves the scale;
    /// enough consecutive clean steps double it.
    pub fn update(&mut self, nonfinite: bool) {
        if !self.enabled {
            if nonfinite {
                self.skipped += 1;
            }
            return;
        }
        if nonfinite {
            self.scale = (self.scale * self.cfg.backoff_factor).max(1.0);
            self.good_steps = 0;
            self.skipped += 1;
        } else {
            self.good_steps += 1;
            if self.good_steps >= self.cfg.growth_interval {
                self.scale = (self.scale * self.cfg.growth_factor).min(self.cfg.max_scale);
                self.good_steps = 0;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_schedule_backs_off_and_recovers() {
        let mut s = GradScaler::new(ScalerConfig::paper());
        assert_eq!(s.scale(), 1e4);
        s.update(true);
        assert_eq!(s.scale(), 5e3);
        assert_eq!(s.skipped, 1);
        for _ in 0..10_000 {
            s.update(false);
        }
        assert_eq!(s.scale(), 1e4);
    }

    #[test]
    fn growth_counter_resets_on_backoff() {
        let mut s = GradScaler::new(ScalerConfig { growth_interval: 10, ..ScalerConfig::paper() });
        for _ in 0..9 {
            s.update(false);
        }
        s.update(true); // resets the streak
        for _ in 0..9 {
            s.update(false);
        }
        assert_eq!(s.scale(), 5e3, "must not have grown yet");
        s.update(false);
        assert_eq!(s.scale(), 1e4);
    }

    #[test]
    fn disabled_never_moves() {
        let mut s = GradScaler::disabled();
        s.update(true);
        s.update(false);
        assert_eq!(s.scale(), 1.0);
        assert_eq!(s.skipped, 1);
    }

    #[test]
    fn scale_floors_at_one_and_caps_at_max() {
        let mut s = GradScaler::new(ScalerConfig {
            init_scale: 2.0,
            growth_interval: 1,
            max_scale: 8.0,
            ..ScalerConfig::paper()
        });
        for _ in 0..10 {
            s.update(true);
        }
        assert_eq!(s.scale(), 1.0);
        for _ in 0..10 {
            s.update(false);
        }
        assert_eq!(s.scale(), 8.0);
    }

    #[test]
    fn ckpt_roundtrip_restores_dynamics() {
        let mut s = GradScaler::new(ScalerConfig { growth_interval: 10, ..ScalerConfig::paper() });
        s.update(true);
        for _ in 0..7 {
            s.update(false);
        }
        let mut enc = crate::ckpt::Enc::new();
        s.ckpt_write(&mut enc);
        let bytes = enc.into_bytes();

        let mut twin = GradScaler::new(ScalerConfig { growth_interval: 10, ..ScalerConfig::paper() });
        let mut dec = crate::ckpt::Dec::new(&bytes);
        twin.ckpt_read(&mut dec).unwrap();
        dec.finish().unwrap();
        assert_eq!(twin.scale(), s.scale());
        assert_eq!(twin.skipped, 1);
        // the clean-step streak survives: 3 more clean steps trigger growth
        for _ in 0..3 {
            s.update(false);
            twin.update(false);
        }
        assert_eq!(twin.scale(), s.scale());
        assert_eq!(twin.scale(), 1e4, "streak of 7 + 3 must double 5e3");
    }

    #[test]
    fn amp_defaults() {
        let s = GradScaler::new(ScalerConfig::amp_default());
        assert_eq!(s.scale(), 65536.0);
    }
}
