//! Run-state (de)serialization shared by the strict and async trainers.
//!
//! A checkpoint payload is the *entire* observable state of a training
//! run at a round boundary — every RNG stream, the env physics and
//! pixel frame stacks, the replay ring, the agent (masters, packed
//! mirrors, Adam moments, Kahan-EMA shadows, loss scalers, agent noise
//! stream), the update-schedule counters, the eval curve, and the
//! gradient histogram — prefixed by a config fingerprint so a
//! checkpoint can never be silently resumed under a different task,
//! preset, storage tier, sync mode, seed, or env count (any of which
//! would break the bitwise-resume contract of `INVARIANTS.md` §8).
//!
//! Layout (strict mode; `Enc` field order is the format):
//!
//! ```text
//! header   task/preset/storage/sync_mode strs, seed, num_envs,
//!          steps, seed_steps, batch, eval_every
//! step     u64    agent env-steps completed
//! rng      u128×2 the shared trainer stream (stream 7)
//! collector  env streams, obs_flat, ep_step, VecEnv state
//! replay   ReplayBuffer::ckpt_write
//! agent    SacAgent::ckpt_write
//! sched    UpdateSchedule::ckpt_write
//! curve    eval points (f64 pairs)
//! hist     grad histogram counters
//! ```
//!
//! Async payloads append a tail: the next round index (the snapshot
//! version clock) and the optional pre-round actor masters needed to
//! republish the lag-2 snapshot window on resume (see
//! `pipeline::train_agent_async`).

use super::trainer::UpdateSchedule;
use crate::ckpt::{CkptStore, Dec, Enc};
use crate::config::RunConfig;
use crate::envs::VecEnv;
use crate::replay::ReplayBuffer;
use crate::rngs::Pcg64;
use crate::sac::SacAgent;
use crate::telemetry::{LogHistogram, Series};
use anyhow::{ensure, Result};
use std::path::{Path, PathBuf};

/// True when the round `[base_step, end_step)` crossed a checkpoint
/// boundary: a checkpoint is due after the round whose end step enters
/// a new multiple of `every`. `every == 0` disables checkpointing.
pub(super) fn ckpt_due(every: usize, base_step: usize, end_step: usize) -> bool {
    every != 0 && end_step / every > base_step / every
}

/// Open the checkpoint store a run reads and writes, or `None` when the
/// config neither checkpoints nor resumes. Writes land in
/// `<out_dir>/ckpt/` unless `resume_from` names a directory, in which
/// case that directory is both the resume source and the ongoing store
/// (the run-forever restart flow: point `resume_from` at the previous
/// attempt's store and keep appending generations to it).
pub(super) fn open_store(cfg: &RunConfig) -> Option<CkptStore> {
    if cfg.checkpoint_every == 0 && cfg.resume_from.is_empty() {
        return None;
    }
    let dir = if cfg.resume_from.is_empty() {
        Path::new(&cfg.out_dir).join("ckpt")
    } else {
        PathBuf::from(&cfg.resume_from)
    };
    // the trainer API is infallible (panics on invalid configs); an
    // unopenable checkpoint dir is the same class of caller error
    Some(CkptStore::open(dir, cfg.ckpt_keep).unwrap_or_else(|e| panic!("{e:#}")))
}

/// Load the newest valid generation, panicking if `resume_from` names a
/// store with nothing valid to resume from — silently starting fresh
/// would masquerade as a resumed run.
pub(super) fn load_resume(cfg: &RunConfig, store: &CkptStore) -> Option<(u64, Vec<u8>)> {
    if cfg.resume_from.is_empty() {
        return None;
    }
    let loaded = store.load_latest().unwrap_or_else(|e| panic!("{e:#}"));
    Some(loaded.unwrap_or_else(|| {
        panic!("resume_from {}: no valid checkpoint generation found", cfg.resume_from)
    }))
}

fn write_header(enc: &mut Enc, cfg: &RunConfig, n: usize) {
    enc.str(&cfg.task);
    enc.str(&cfg.preset);
    enc.str(&cfg.storage);
    enc.str(&cfg.replay_storage);
    enc.str(&cfg.sync_mode);
    enc.u64(cfg.seed);
    enc.u64(n as u64);
    enc.u64(cfg.steps as u64);
    enc.u64(cfg.seed_steps as u64);
    enc.u64(cfg.batch as u64);
    enc.u64(cfg.eval_every.max(1) as u64);
}

fn read_header(dec: &mut Dec, cfg: &RunConfig, n: usize) -> Result<()> {
    let strs = [
        ("task", cfg.task.as_str()),
        ("preset", cfg.preset.as_str()),
        ("storage", cfg.storage.as_str()),
        ("replay_storage", cfg.replay_storage.as_str()),
        ("sync_mode", cfg.sync_mode.as_str()),
    ];
    for (name, want) in strs {
        let got = dec.str()?;
        ensure!(
            got == want,
            "checkpoint was written with {name}={got:?}, this run uses {name}={want:?}"
        );
    }
    let nums = [
        ("seed", cfg.seed),
        ("num_envs", n as u64),
        ("steps", cfg.steps as u64),
        ("seed_steps", cfg.seed_steps as u64),
        ("batch", cfg.batch as u64),
        ("eval_every", cfg.eval_every.max(1) as u64),
    ];
    for (name, want) in nums {
        let got = dec.u64()?;
        ensure!(
            got == want,
            "checkpoint was written with {name}={got}, this run uses {name}={want}"
        );
    }
    Ok(())
}

pub(super) fn write_rng(enc: &mut Enc, rng: &Pcg64) {
    let (state, inc) = rng.raw_state();
    enc.u128(state);
    enc.u128(inc);
}

pub(super) fn read_rng(dec: &mut Dec) -> Result<Pcg64> {
    let state = dec.u128()?;
    let inc = dec.u128()?;
    Ok(Pcg64::from_raw_state(state, inc))
}

/// Serialize the collector's half of the run state: the per-env RNG
/// streams, the staged observations, the per-env episode clocks, and
/// the env physics/frame state. In async mode this section is produced
/// by the collector thread and spliced into the learner's payload
/// verbatim ([`Enc::raw`]); the strict trainer writes it inline.
pub(super) fn write_collector(
    enc: &mut Enc,
    env_rngs: &[Pcg64],
    obs_flat: &[f32],
    ep_step: &[usize],
    venv: &VecEnv,
) {
    enc.u64(env_rngs.len() as u64);
    for r in env_rngs {
        write_rng(enc, r);
    }
    enc.f32s(obs_flat);
    enc.u64(ep_step.len() as u64);
    for &e in ep_step {
        enc.u64(e as u64);
    }
    venv.ckpt_write(enc);
}

pub(super) fn read_collector(
    dec: &mut Dec,
    env_rngs: &mut [Pcg64],
    obs_flat: &mut [f32],
    ep_step: &mut [usize],
    venv: &mut VecEnv,
) -> Result<()> {
    let nr = dec.usize()?;
    ensure!(
        nr == env_rngs.len(),
        "checkpoint holds {nr} env RNG streams, this run has {}",
        env_rngs.len()
    );
    for r in env_rngs.iter_mut() {
        *r = read_rng(dec)?;
    }
    dec.f32s_into(obs_flat)?;
    let ne = dec.usize()?;
    ensure!(
        ne == ep_step.len(),
        "checkpoint holds {ne} episode clocks, this run has {}",
        ep_step.len()
    );
    for e in ep_step.iter_mut() {
        *e = dec.usize()?;
    }
    venv.ckpt_read(dec)
}

fn write_series(enc: &mut Enc, s: &Series) {
    enc.u64(s.points.len() as u64);
    for &(x, y) in &s.points {
        enc.f64(x);
        enc.f64(y);
    }
}

fn read_series(dec: &mut Dec, s: &mut Series) -> Result<()> {
    let n = dec.usize()?;
    s.points.clear();
    for _ in 0..n {
        let x = dec.f64()?;
        let y = dec.f64()?;
        s.points.push((x, y));
    }
    Ok(())
}

fn write_hist(enc: &mut Enc, h: &LogHistogram) {
    enc.u64s(&h.counts);
    enc.u64(h.underflow);
    enc.u64(h.overflow);
}

fn read_hist(dec: &mut Dec, h: &mut LogHistogram) -> Result<()> {
    let counts = dec.u64s()?;
    ensure!(
        counts.len() == h.counts.len(),
        "checkpoint histogram has {} bins, this run's has {}",
        counts.len(),
        h.counts.len()
    );
    h.counts = counts;
    h.underflow = dec.u64()?;
    h.overflow = dec.u64()?;
    Ok(())
}

/// The learner-side tail shared by both sync modes: replay ring, agent,
/// schedule counters, eval curve, gradient histogram.
#[allow(clippy::too_many_arguments)]
fn write_learner(
    enc: &mut Enc,
    replay: &ReplayBuffer,
    agent: &SacAgent,
    sched: &UpdateSchedule,
    eval_curve: &Series,
    grad_hist: &LogHistogram,
) {
    replay.ckpt_write(enc);
    agent.ckpt_write(enc);
    sched.ckpt_write(enc);
    write_series(enc, eval_curve);
    write_hist(enc, grad_hist);
}

#[allow(clippy::too_many_arguments)]
fn read_learner(
    dec: &mut Dec,
    replay: &mut ReplayBuffer,
    agent: &mut SacAgent,
    sched: &mut UpdateSchedule,
    eval_curve: &mut Series,
    grad_hist: &mut LogHistogram,
) -> Result<()> {
    replay.ckpt_read(dec)?;
    agent.ckpt_read(dec)?;
    sched.ckpt_read(dec)?;
    read_series(dec, eval_curve)?;
    read_hist(dec, grad_hist)
}

/// Encode one strict-mode checkpoint payload.
#[allow(clippy::too_many_arguments)]
pub(super) fn save_strict(
    cfg: &RunConfig,
    n: usize,
    step: usize,
    rng: &Pcg64,
    env_rngs: &[Pcg64],
    obs_flat: &[f32],
    ep_step: &[usize],
    venv: &VecEnv,
    replay: &ReplayBuffer,
    agent: &SacAgent,
    sched: &UpdateSchedule,
    eval_curve: &Series,
    grad_hist: &LogHistogram,
) -> Vec<u8> {
    let mut enc = Enc::new();
    write_header(&mut enc, cfg, n);
    enc.u64(step as u64);
    write_rng(&mut enc, rng);
    write_collector(&mut enc, env_rngs, obs_flat, ep_step, venv);
    write_learner(&mut enc, replay, agent, sched, eval_curve, grad_hist);
    enc.into_bytes()
}

/// Decode a strict-mode payload into live run state; returns the
/// resumed step count.
#[allow(clippy::too_many_arguments)]
pub(super) fn resume_strict(
    payload: &[u8],
    cfg: &RunConfig,
    n: usize,
    rng: &mut Pcg64,
    env_rngs: &mut [Pcg64],
    obs_flat: &mut [f32],
    ep_step: &mut [usize],
    venv: &mut VecEnv,
    replay: &mut ReplayBuffer,
    agent: &mut SacAgent,
    sched: &mut UpdateSchedule,
    eval_curve: &mut Series,
    grad_hist: &mut LogHistogram,
) -> Result<usize> {
    let mut dec = Dec::new(payload);
    read_header(&mut dec, cfg, n)?;
    let step = dec.usize()?;
    *rng = read_rng(&mut dec)?;
    read_collector(&mut dec, env_rngs, obs_flat, ep_step, venv)?;
    read_learner(&mut dec, replay, agent, sched, eval_curve, grad_hist)?;
    dec.finish()?;
    Ok(step)
}

/// The async-only tail decoded by [`resume_async`]: where the round
/// clock resumes and the pre-round actor masters (present only when the
/// checkpointed round ran updates) that rebuild the lag-2 snapshot
/// window.
pub(super) struct AsyncResume {
    pub step: usize,
    pub next_round: usize,
    /// `Some((actor_flat, enc_flat))` ⇒ snapshot version `next_round-1`
    /// differs from the current masters and must be rebuilt via
    /// `SacAgent::policy_from_flats`.
    pub pre_actor: Option<(Vec<f32>, Option<Vec<f32>>)>,
}

/// Encode one async-mode checkpoint payload. `collector_blob` is the
/// [`write_collector`] section the collector thread shipped across the
/// queue; `pre_actor` is `Some` iff the checkpointed round ran updates.
#[allow(clippy::too_many_arguments)]
pub(super) fn save_async(
    cfg: &RunConfig,
    n: usize,
    step: usize,
    rng: &Pcg64,
    collector_blob: &[u8],
    replay: &ReplayBuffer,
    agent: &SacAgent,
    sched: &UpdateSchedule,
    eval_curve: &Series,
    grad_hist: &LogHistogram,
    next_round: usize,
    pre_actor: Option<&(Vec<f32>, Option<Vec<f32>>)>,
) -> Vec<u8> {
    let mut enc = Enc::new();
    write_header(&mut enc, cfg, n);
    enc.u64(step as u64);
    write_rng(&mut enc, rng);
    enc.raw(collector_blob);
    write_learner(&mut enc, replay, agent, sched, eval_curve, grad_hist);
    enc.u64(next_round as u64);
    match pre_actor {
        None => enc.bool(false),
        Some((actor_flat, enc_flat)) => {
            enc.bool(true);
            enc.f32s(actor_flat);
            match enc_flat {
                None => enc.bool(false),
                Some(e) => {
                    enc.bool(true);
                    enc.f32s(e);
                }
            }
        }
    }
    enc.into_bytes()
}

/// Decode an async-mode payload into live run state.
#[allow(clippy::too_many_arguments)]
pub(super) fn resume_async(
    payload: &[u8],
    cfg: &RunConfig,
    n: usize,
    rng: &mut Pcg64,
    env_rngs: &mut [Pcg64],
    obs_flat: &mut [f32],
    ep_step: &mut [usize],
    venv: &mut VecEnv,
    replay: &mut ReplayBuffer,
    agent: &mut SacAgent,
    sched: &mut UpdateSchedule,
    eval_curve: &mut Series,
    grad_hist: &mut LogHistogram,
) -> Result<AsyncResume> {
    let mut dec = Dec::new(payload);
    read_header(&mut dec, cfg, n)?;
    let step = dec.usize()?;
    *rng = read_rng(&mut dec)?;
    read_collector(&mut dec, env_rngs, obs_flat, ep_step, venv)?;
    read_learner(&mut dec, replay, agent, sched, eval_curve, grad_hist)?;
    let next_round = dec.usize()?;
    let pre_actor = if dec.bool()? {
        let actor_flat = dec.f32s()?;
        let enc_flat = if dec.bool()? { Some(dec.f32s()?) } else { None };
        Some((actor_flat, enc_flat))
    } else {
        None
    };
    dec.finish()?;
    Ok(AsyncResume { step, next_round, pre_actor })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ckpt_due_fires_on_multiple_crossings_only() {
        assert!(!ckpt_due(0, 0, 100), "every=0 disables checkpointing");
        assert!(ckpt_due(50, 48, 52), "round crossing a multiple is due");
        assert!(ckpt_due(50, 46, 50), "round ending exactly on a multiple is due");
        assert!(!ckpt_due(50, 50, 54), "round starting on a multiple is not due again");
        assert!(!ckpt_due(50, 10, 14));
        assert!(ckpt_due(1, 3, 4), "every=1 checkpoints every round");
    }

    #[test]
    fn header_rejects_mismatched_configs() {
        let cfg = RunConfig { task: "pendulum_swingup".into(), ..Default::default() };
        let mut enc = Enc::new();
        write_header(&mut enc, &cfg, 4);
        let bytes = enc.into_bytes();
        read_header(&mut Dec::new(&bytes), &cfg, 4).unwrap();

        let other = RunConfig { task: "cartpole_balance".into(), ..cfg.clone() };
        let err = read_header(&mut Dec::new(&bytes), &other, 4).unwrap_err();
        assert!(format!("{err}").contains("task"), "{err}");
        let err = read_header(&mut Dec::new(&bytes), &cfg, 5).unwrap_err();
        assert!(format!("{err}").contains("num_envs"), "{err}");
        let mut seeded = cfg.clone();
        seeded.seed = 9;
        let err = read_header(&mut Dec::new(&bytes), &seeded, 4).unwrap_err();
        assert!(format!("{err}").contains("seed"), "{err}");
    }

    #[test]
    fn series_and_hist_roundtrip() {
        let mut s = Series::new("x");
        s.push(1.0, 2.5);
        s.push(2.0, -0.5);
        let mut h = LogHistogram::new(-12, 4, 2);
        h.record(1e-3);
        h.record(1e30); // overflow bin
        let mut enc = Enc::new();
        write_series(&mut enc, &s);
        write_hist(&mut enc, &h);
        let bytes = enc.into_bytes();
        let mut dec = Dec::new(&bytes);
        let mut s2 = Series::new("x");
        let mut h2 = LogHistogram::new(-12, 4, 2);
        read_series(&mut dec, &mut s2).unwrap();
        read_hist(&mut dec, &mut h2).unwrap();
        dec.finish().unwrap();
        assert_eq!(s2.points, s.points);
        assert_eq!(h2.counts, h.counts);
        assert_eq!(h2.overflow, 1);
        // a histogram of a different shape refuses the counters
        let mut enc = Enc::new();
        write_hist(&mut enc, &h);
        let bytes = enc.into_bytes();
        let mut wrong = LogHistogram::new(-3, 3, 2);
        let err = read_hist(&mut Dec::new(&bytes), &mut wrong).unwrap_err();
        assert!(format!("{err}").contains("bins"), "{err}");
    }
}
