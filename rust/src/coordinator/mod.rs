//! Training coordinator: the collector/learner loop over vectorized
//! environments (collect → update → eval rounds, episode time limits +
//! action repeat), batched deterministic evaluation, crash accounting,
//! and multi-seed parallel orchestration for the experiment harness.
//!
//! Two interleave contracts, selected by `RunConfig::sync_mode`:
//! [`trainer`]'s strict loop (collect, update and eval alternate in one
//! thread — the bitwise reference) and [`pipeline`]'s async loop (the
//! collector runs in its own thread on lagged policy snapshots with
//! pooled parallel env stepping, overlapping physics/rendering with the
//! learner's GEMMs).

mod pipeline;
mod run_state;
mod trainer;

// `PixelEnvAdapter` moved into `envs` (it is an env concern and
// `envs::VecEnv` consumes it); re-exported here for compatibility.
pub use crate::envs::PixelEnvAdapter;
pub use trainer::{
    evaluate_policy, evaluate_policy_batched, run_many, train, TrainOutcome,
    FINGERPRINT_MAX_FLOATS,
};

/// dm_control episode length in raw environment steps.
pub const EPISODE_ENV_STEPS: usize = 1000;
