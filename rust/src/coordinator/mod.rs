//! Training coordinator: the seed/collect/update loop, episode
//! management (time limits + action repeat), evaluation, pixel
//! frame-stacking, crash accounting, and multi-seed parallel
//! orchestration for the experiment harness.

mod pixels;
mod trainer;

pub use pixels::PixelEnvAdapter;
pub use trainer::{evaluate_policy, evaluate_policy_batched, run_many, train, TrainOutcome};

/// dm_control episode length in raw environment steps.
pub const EPISODE_ENV_STEPS: usize = 1000;
