//! Asynchronous collector/learner pipeline (`sync_mode = "async"`).
//!
//! The strict trainer interleaves collect → update → eval in one
//! thread, so the learner idles while physics/rendering runs and the
//! collector idles during GEMMs. This module runs them concurrently:
//!
//! * the **collector** thread steps the `VecEnv` streams on an
//!   immutable [`Policy`] snapshot, fanning per-env physics/rendering
//!   across its own [`ThreadPool`] (`min(num_envs, default_threads())`
//!   lanes — separate from the GEMM pool so env stepping never falls
//!   back inline just because the learner is inside a GEMM), and feeds
//!   transition chunks through a bounded queue;
//! * the **learner** (the calling thread) drains chunks, pushes them
//!   into replay (`ReplayBuffer::push_batch`), runs the SAC
//!   1-update-per-transition schedule against the same step-budget
//!   accountant as the strict loop (update counts match it exactly),
//!   evaluates on the same step grid, and republishes a fresh policy
//!   snapshot every round.
//!
//! ## Determinism contract (relaxed, but still exact)
//!
//! Rounds are the same schedule the strict trainer uses (round = up to
//! `num_envs` transitions, clipped at seed-phase and eval boundaries).
//! The snapshot protocol is **deterministically lagged**: the actions
//! of round `r` always come from the weights after round
//! `r - PIPELINE_LAG`'s updates (clamped to the initial weights for the
//! first rounds), never from "whatever is freshest". Queue timing
//! therefore affects
//! *wall time only* — two async runs of the same config are bitwise
//! identical, and the whole run is deterministic in `cfg.seed`.
//!
//! Relative to strict mode the contract is relaxed, not broken:
//!
//! * the update count and the eval step grid are identical (tested);
//! * seed-phase transitions are bitwise identical for `num_envs > 1`
//!   (same per-env streams → same multiset in replay, tested via
//!   [`ReplayBuffer::fingerprint`]);
//! * post-seed transitions differ only through the policy lag (the
//!   collector acts with weights `PIPELINE_LAG - 1` rounds stale), so
//!   async eval curves are *not* bitwise-equal to strict ones;
//! * `num_envs = 1` async uses the per-env stream layout (not the
//!   legacy shared stream strict keeps for bitwise seed-compat).
//!
//! Backpressure: the queue holds at most `cfg.queue_rounds` unconsumed
//! rounds; a full queue blocks the collector, an empty one blocks the
//! learner, and both resume without affecting results (only timing).
//! Crash accounting matches the strict loop: a non-finite action in the
//! collector (or an eval-time crash in the learner) scores the run 0
//! from then on and pads the curve.

use super::run_state;
use super::trainer::{
    evaluate, replay_fingerprint_capped, round_len, TrainOutcome, UpdateSchedule, ENV_STREAM_BASE,
};
use crate::ckpt::{Enc, FaultPlan, KillPhase};
use crate::config::RunConfig;
use crate::envs::{sanitize_action, VecEnv};
use crate::nn::pool::{default_threads, ThreadPool};
use crate::nn::Tensor;
use crate::replay::{ReplayBuffer, RoundArena, Storage};
use crate::rngs::Pcg64;
use crate::sac::{ActMode, Policy, SacAgent};
use crate::telemetry::{LogHistogram, Series};
use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::Instant;

/// Snapshot lag in rounds: round `r` acts with the weights left by
/// round `r - PIPELINE_LAG`'s updates (the initial weights for early
/// rounds). Lag 2 is the minimum that lets the collector collect round
/// `r` while the learner is still updating on round `r - 1`; a larger
/// lag would only add policy staleness, not overlap.
const PIPELINE_LAG: u64 = 2;

/// Lazy walk of the collect-round schedule: `(round, base_step, k)`
/// per round, where `k ≤ num_envs` transitions are collected and
/// rounds never straddle the seed-phase or an eval boundary. Both
/// pipeline threads iterate their own copy and the strict trainer
/// computes the same splits online — all three through the single
/// `trainer::round_len` rule, so the update count and eval grid are
/// `sync_mode`-invariant by construction (and nothing materializes a
/// paper-scale schedule as a Vec).
struct Rounds<'a> {
    cfg: &'a RunConfig,
    n: usize,
    step: usize,
    round: usize,
}

fn rounds(cfg: &RunConfig, n: usize) -> Rounds<'_> {
    rounds_from(cfg, n, 0, 0)
}

/// The schedule walk from a mid-run position — how a resumed run
/// re-enters the round sequence: `round_len` depends only on `step`, so
/// walking from `(step, round)` yields exactly the suffix the
/// uninterrupted walk would have produced.
fn rounds_from(cfg: &RunConfig, n: usize, step: usize, round: usize) -> Rounds<'_> {
    Rounds { cfg, n, step, round }
}

impl Iterator for Rounds<'_> {
    type Item = (usize, usize, usize);

    fn next(&mut self) -> Option<(usize, usize, usize)> {
        if self.step >= self.cfg.steps {
            return None;
        }
        let k = round_len(self.cfg, self.n, self.step);
        let item = (self.round, self.step, k);
        self.step += k;
        self.round += 1;
        Some(item)
    }
}

/// One collect round crossing the thread boundary: `k` transitions in
/// flat row-major chunks, exactly the `ReplayBuffer::push_batch` layout.
/// Consumed chunks flow back to the collector through the queue's spare
/// stack ([`Queue::recycle`]), so the steady-state pipeline re-fills
/// existing vectors instead of allocating fresh ones every round — for
/// pixel observations the obs/next-obs chunks are by far the largest
/// recurring allocation the async trainer made.
#[derive(Default)]
struct Chunk {
    base_step: usize,
    k: usize,
    obs: Vec<f32>,
    act: Vec<f32>,
    rew: Vec<f32>,
    next_obs: Vec<f32>,
}

impl Chunk {
    /// Re-fill a (possibly recycled) chunk in place: `clear` +
    /// `extend_from_slice` keeps each vector's capacity, so a chunk that
    /// has been through the queue once never reallocates.
    #[allow(clippy::too_many_arguments)]
    fn fill(
        &mut self,
        base_step: usize,
        k: usize,
        obs: &[f32],
        act: &[f32],
        rew: &[f32],
        next_obs: &[f32],
    ) {
        self.base_step = base_step;
        self.k = k;
        self.obs.clear();
        self.obs.extend_from_slice(obs);
        self.act.clear();
        self.act.extend_from_slice(act);
        self.rew.clear();
        self.rew.extend_from_slice(rew);
        self.next_obs.clear();
        self.next_obs.extend_from_slice(next_obs);
    }
}

enum Msg {
    Chunk(Chunk),
    /// The collector's serialized half of a due checkpoint
    /// ([`run_state::write_collector`]), pushed immediately after the
    /// due round's `Chunk` — FIFO ordering guarantees the learner pops
    /// it exactly when it assembles that round's checkpoint.
    CkptState(Vec<u8>),
    /// The collector hit a non-finite action (the paper's crash
    /// condition) and stopped.
    Crash,
}

/// Bounded transition queue (mutex + condvars — one lock round-trip per
/// *round*, not per transition, so the lock is far off the hot path).
struct Queue {
    q: Mutex<VecDeque<Msg>>,
    cap: usize,
    not_full: Condvar,
    not_empty: Condvar,
    /// Learner-initiated abort (crash mid-run): unblocks the collector.
    stop: AtomicBool,
    /// Collector exited (normally or by panic): unblocks the learner.
    closed: AtomicBool,
    /// Consumed chunks flowing back to the collector for reuse, bounded
    /// by the queue depth (at most `cap + 1` chunks are ever in flight:
    /// `cap` queued plus the one the collector is filling).
    spare: Mutex<Vec<Chunk>>,
}

impl Queue {
    fn new(cap: usize) -> Queue {
        Queue {
            q: Mutex::new(VecDeque::new()),
            cap: cap.max(1),
            not_full: Condvar::new(),
            not_empty: Condvar::new(),
            stop: AtomicBool::new(false),
            closed: AtomicBool::new(false),
            spare: Mutex::new(Vec::new()),
        }
    }

    /// A recycled chunk if one is waiting, else a fresh (empty) one.
    /// Never blocks.
    fn take_spare(&self) -> Chunk {
        // tidy-allow(panic): lock poisoning means the other side already
        // panicked — propagating is correct (applies to every queue lock
        // and condvar wait in this module).
        self.spare.lock().unwrap().pop().unwrap_or_default()
    }

    /// Hand a consumed chunk back to the collector. Drops the chunk
    /// instead of hoarding it once the spare stack covers the maximum
    /// number in flight.
    fn recycle(&self, chunk: Chunk) {
        let mut g = self.spare.lock().unwrap(); // tidy-allow(panic): poisoned lock — see take_spare
        if g.len() <= self.cap {
            g.push(chunk);
        }
    }

    /// Blocking push with backpressure; returns `false` if the learner
    /// asked the pipeline to stop.
    fn push(&self, m: Msg) -> bool {
        let mut g = self.q.lock().unwrap(); // tidy-allow(panic): poisoned lock — see take_spare
        loop {
            if self.stop.load(Ordering::Acquire) {
                return false;
            }
            if g.len() < self.cap {
                g.push_back(m);
                drop(g);
                self.not_empty.notify_one();
                return true;
            }
            g = self.not_full.wait(g).unwrap(); // tidy-allow(panic): poisoned lock — see take_spare
        }
    }

    /// Blocking pop; `None` means the collector is gone and nothing is
    /// left to drain (it died — a normally-finished collector has
    /// already queued every scheduled round).
    fn pop(&self) -> Option<Msg> {
        let mut g = self.q.lock().unwrap(); // tidy-allow(panic): poisoned lock — see take_spare
        loop {
            if let Some(m) = g.pop_front() {
                drop(g);
                self.not_full.notify_one();
                return Some(m);
            }
            if self.closed.load(Ordering::Acquire) {
                return None;
            }
            g = self.not_empty.wait(g).unwrap(); // tidy-allow(panic): poisoned lock — see take_spare
        }
    }

    /// Learner-side abort: wake a collector blocked on a full queue.
    fn stop(&self) {
        self.stop.store(true, Ordering::Release);
        let _g = self.q.lock().unwrap(); // tidy-allow(panic): poisoned lock — see take_spare
        self.not_full.notify_all();
    }

    /// Collector-side close: wake a learner blocked on an empty queue.
    /// Runs in a drop guard so a panicking collector still closes.
    fn close(&self) {
        self.closed.store(true, Ordering::Release);
        let _g = self.q.lock().unwrap(); // tidy-allow(panic): poisoned lock — see take_spare
        self.not_empty.notify_all();
    }
}

/// Closes the queue when the collector exits — including by panic, so
/// the learner never deadlocks on a dead producer.
struct CloseGuard<'a>(&'a Queue);

impl Drop for CloseGuard<'_> {
    fn drop(&mut self) {
        self.0.close();
    }
}

/// The learner-side twin of [`CloseGuard`]: stops the collector's
/// blocking waits when the learner body exits — including by panic.
/// Without it, a panicking learner would unwind into
/// `std::thread::scope`'s implicit join while the collector is parked
/// on a full queue or an unpublished snapshot version, deadlocking the
/// process instead of propagating the panic.
struct StopGuard<'a>(&'a Queue, &'a SnapshotSlot);

impl Drop for StopGuard<'_> {
    fn drop(&mut self) {
        self.0.stop();
        self.1.stop();
    }
}

/// The versioned snapshot slot: the learner publishes `(version, Arc)`
/// pairs, the collector fetches *exact* versions. Keeping the last
/// `PIPELINE_LAG + 1` publications is enough: the collector's needed
/// version trails the newest publication by at most `PIPELINE_LAG`
/// (the learner cannot process a round whose chunk has not been
/// collected yet).
#[derive(Default)]
struct SnapshotSlot {
    inner: Mutex<VecDeque<(u64, Arc<Policy>)>>,
    cv: Condvar,
    stop: AtomicBool,
}

impl SnapshotSlot {
    fn publish(&self, version: u64, policy: Arc<Policy>) {
        let mut g = self.inner.lock().unwrap(); // tidy-allow(panic): poisoned lock — see take_spare
        g.push_back((version, policy));
        while g.len() > PIPELINE_LAG as usize + 1 {
            g.pop_front();
        }
        drop(g);
        self.cv.notify_all();
    }

    /// Block until `version` is published and return it; `None` on stop.
    fn fetch(&self, version: u64) -> Option<Arc<Policy>> {
        let mut g = self.inner.lock().unwrap(); // tidy-allow(panic): poisoned lock — see take_spare
        loop {
            if let Some((_, p)) = g.iter().find(|(v, _)| *v == version) {
                return Some(p.clone());
            }
            if self.stop.load(Ordering::Acquire) {
                return None;
            }
            g = self.cv.wait(g).unwrap(); // tidy-allow(panic): poisoned lock — see take_spare
        }
    }

    fn stop(&self) {
        self.stop.store(true, Ordering::Release);
        let _g = self.inner.lock().unwrap(); // tidy-allow(panic): poisoned lock — see take_spare
        self.cv.notify_all();
    }
}

/// The collector's mutable state, initialized (fresh or from a
/// checkpoint) by the learner thread before the collector spawns, so
/// resume restores both halves of the pipeline from one payload.
struct CollectorInit {
    /// Per-env streams (resets + seed-phase actions + exploration
    /// noise) — async mode always uses this layout, including n = 1.
    env_rngs: Vec<Pcg64>,
    obs_flat: Vec<f32>,
    ep_step: Vec<usize>,
    start_step: usize,
    start_round: usize,
}

/// The collector thread body: walk the round schedule, act on the
/// deterministically-lagged snapshot, step the env streams across the
/// env pool, queue the chunk (plus its serialized state after rounds
/// that cross a checkpoint boundary). Returns the productive collect
/// wall time (queue/snapshot waits excluded — they are the pipeline's
/// slack, not collection work).
fn collector(
    mut venv: VecEnv,
    cfg: &RunConfig,
    queue: &Queue,
    slot: &SnapshotSlot,
    env_pool: &ThreadPool,
    init: CollectorInit,
) -> f64 {
    let _close = CloseGuard(queue);
    let n = venv.num_envs();
    let obs_len = venv.obs_len();
    let act_dim = venv.act_dim();
    let episode_steps = super::EPISODE_ENV_STEPS / venv.action_repeat();
    let CollectorInit { mut env_rngs, mut obs_flat, mut ep_step, start_step, start_round } = init;
    let mut next_flat = vec![0.0f32; n * obs_len];
    let mut rew_buf = vec![0.0f32; n];
    let mut obs_stage = Tensor::default();
    let mut collect_secs = 0.0f64;
    // Claim-grain policy: pixel steps (physics + rendering + frame
    // stack) are heavy, so claim one env per RMW for load balance;
    // state steps are a handful of RK4 microseconds, so chunk them to
    // one claim per lane and skip the per-env atomic traffic.
    let pixels = venv.obs_shape().len() == 3;
    let lanes = env_pool.workers + 1;

    for (round, base_step, k) in rounds_from(cfg, n, start_step, start_round) {
        // Resolve the round's policy before starting the timer: the
        // fetch may block on the learner, and that wait is pipeline
        // slack, not collection work.
        let policy = if base_step < cfg.seed_steps {
            None
        } else {
            let version = (round as u64 + 1).saturating_sub(PIPELINE_LAG);
            match slot.fetch(version) {
                Some(p) => Some(p),
                None => return collect_secs, // learner aborted
            }
        };

        // tidy-allow(determinism): wall-clock feeds throughput telemetry
        // only — no training decision reads it.
        let tc = Instant::now();
        let mut acts = match policy {
            None => {
                let mut t = Tensor::zeros(&[k, act_dim]);
                for i in 0..k {
                    for v in t.row_mut(i) {
                        *v = env_rngs[i].uniform_in(-1.0, 1.0);
                    }
                }
                t
            }
            Some(p) => {
                let obs_t = p.stage_obs(&mut obs_stage, &obs_flat[..k * obs_len], k);
                p.act_batch(obs_t, ActMode::SamplePerEnv(&mut env_rngs[..k]))
            }
        };
        let mut crashed = false;
        for i in 0..k {
            if !sanitize_action(acts.row_mut(i)) {
                crashed = true;
            }
        }
        if crashed {
            collect_secs += tc.elapsed().as_secs_f64();
            queue.push(Msg::Crash);
            return collect_secs;
        }
        let grain = if pixels { 1 } else { k.div_ceil(lanes) };
        venv.par_step_into(k, &acts, &mut next_flat[..k * obs_len], &mut rew_buf[..k], env_pool, grain);
        let mut chunk = queue.take_spare();
        chunk.fill(
            base_step,
            k,
            &obs_flat[..k * obs_len],
            &acts.data,
            &rew_buf[..k],
            &next_flat[..k * obs_len],
        );
        obs_flat[..k * obs_len].copy_from_slice(&next_flat[..k * obs_len]);
        for i in 0..k {
            ep_step[i] += 1;
            if ep_step[i] >= episode_steps {
                venv.reset_into(i, &mut env_rngs[i], &mut obs_flat[i * obs_len..(i + 1) * obs_len]);
                ep_step[i] = 0;
            }
        }
        collect_secs += tc.elapsed().as_secs_f64();
        if !queue.push(Msg::Chunk(chunk)) {
            return collect_secs; // learner aborted
        }
        // After a round that crosses a checkpoint boundary, ship this
        // thread's half of the run state right behind the chunk; the
        // learner pops it when it assembles the checkpoint. Both
        // threads walk the same schedule, so due-ness needs no
        // cross-thread coordination.
        if run_state::ckpt_due(cfg.checkpoint_every, base_step, base_step + k) {
            let mut enc = Enc::new();
            run_state::write_collector(&mut enc, &env_rngs, &obs_flat, &ep_step, &venv);
            if !queue.push(Msg::CkptState(enc.into_bytes())) {
                return collect_secs; // learner aborted
            }
        }
    }
    collect_secs
}

/// The async collector/learner pipeline over a pre-built agent — the
/// seam the crash-path tests use to inject poisoned weights (the async
/// twin of the strict `train_agent`). Called via `coordinator::train`
/// when `cfg.sync_mode == "async"`.
pub(super) fn train_agent_async(
    cfg: &RunConfig,
    mut venv: VecEnv,
    mut agent: SacAgent,
) -> TrainOutcome {
    // tidy-allow(determinism): wall-clock feeds throughput telemetry
    // only — no training decision reads it.
    let t0 = Instant::now();
    let n = venv.num_envs();
    let obs_len = venv.obs_len();
    let repeat = venv.action_repeat();
    let act_dim = venv.act_dim();
    let eval_every = cfg.eval_every.max(1);
    let queue = Queue::new(cfg.queue_rounds);
    let slot = SnapshotSlot::default();
    let env_pool = ThreadPool::new(n.min(default_threads()));

    // Learner-side state: the shared trainer stream drives replay
    // sampling only (env streams live in the collector).
    let mut rng = Pcg64::seed_stream(cfg.seed, 7);
    let storage = cfg.replay_storage(agent.compute.is_low());
    let mut replay = ReplayBuffer::new(cfg.replay_capacity, venv.obs_shape(), act_dim, storage);
    let mut eval_curve = Series::new(format!("{}:{}", cfg.task, cfg.preset));
    let mut grad_hist = LogHistogram::new(-12, 4, 2);
    let mut sched = UpdateSchedule::new(cfg);
    let mut arena = RoundArena::default();
    let done_buf = vec![false; n];

    // Collector-side state, initialized here (fresh or from a
    // checkpoint) so one payload restores both pipeline halves.
    let mut env_rngs: Vec<Pcg64> =
        (0..n).map(|i| Pcg64::seed_stream(cfg.seed, ENV_STREAM_BASE + i as u64)).collect();
    let mut obs_flat = vec![0.0f32; n * obs_len];
    let mut ep_step = vec![0usize; n];

    // -- checkpoint / resume / fault-injection wiring ------------------
    let mut faults =
        FaultPlan::parse(&cfg.faults).unwrap_or_else(|e| panic!("bad faults spec: {e}"));
    let mut store = run_state::open_store(cfg);
    if let Some(st) = store.as_mut() {
        st.arm_torn(faults.torn.take());
    }
    let mut killed = false;
    let mut start_step = 0usize;
    let mut start_round = 0usize;
    let mut pre_actor: Option<(Vec<f32>, Option<Vec<f32>>)> = None;
    match store.as_ref().and_then(|st| run_state::load_resume(cfg, st)) {
        None => {
            for i in 0..n {
                venv.reset_into(
                    i,
                    &mut env_rngs[i],
                    &mut obs_flat[i * obs_len..(i + 1) * obs_len],
                );
            }
        }
        Some((_, payload)) => {
            let r = run_state::resume_async(
                &payload,
                cfg,
                n,
                &mut rng,
                &mut env_rngs,
                &mut obs_flat,
                &mut ep_step,
                &mut venv,
                &mut replay,
                &mut agent,
                &mut sched,
                &mut eval_curve,
                &mut grad_hist,
            )
            .unwrap_or_else(|e| panic!("resume_from {}: {e:#}", cfg.resume_from));
            start_step = r.step;
            start_round = r.next_round;
            pre_actor = r.pre_actor;
        }
    }
    let init = CollectorInit {
        env_rngs,
        obs_flat,
        ep_step,
        start_step,
        start_round,
    };

    let mut crashed = false;
    let mut update_secs = 0.0f64;
    let mut snapshot_refreshes = 0u64;
    let mut snapshot_publish_secs = 0.0f64;
    let mut step = start_step;

    // Publish the snapshot window the collector's first fetches need.
    // Fresh run: version 0 = the initial weights, published before the
    // collector starts so round 0's fetch never waits. Resumed run: a
    // checkpoint taken after round r resumes at round r+1, whose fetch
    // (and round r+2's) need versions r and r+1 — version r+1 is the
    // current restored masters; version r differs only if round r ran
    // updates, in which case the checkpoint carried the pre-round actor
    // masters and the lag-2 schedule is reconstructed from them, not
    // restarted.
    let mut last_snapshot = Arc::new(agent.policy());
    if start_round == 0 {
        slot.publish(0, last_snapshot.clone());
    } else {
        let v_prev = start_round as u64 - 1;
        match &pre_actor {
            Some((actor_flat, enc_flat)) => slot.publish(
                v_prev,
                Arc::new(agent.policy_from_flats(actor_flat, enc_flat.as_deref())),
            ),
            None => slot.publish(v_prev, last_snapshot.clone()),
        }
        slot.publish(start_round as u64, last_snapshot.clone());
    }

    // tidy-allow(determinism): the collector/learner split is the one
    // sanctioned structured-concurrency seam; round schedule, snapshot
    // lag, and env stepping stay bitwise reproducible by construction.
    let collect_secs = std::thread::scope(|s| {
        let handle = {
            let queue = &queue;
            let slot = &slot;
            let env_pool = &env_pool;
            s.spawn(move || collector(venv, cfg, queue, slot, env_pool, init))
        };
        let _stop = StopGuard(&queue, &slot);

        let mut collector_died = false;
        'learn: for (round, base_step, k) in rounds_from(cfg, n, start_step, start_round) {
            match queue.pop() {
                None => {
                    collector_died = true;
                    break 'learn;
                }
                Some(Msg::CkptState(_)) => {
                    // state blobs only ever follow the due round's chunk
                    collector_died = true;
                    break 'learn;
                }
                Some(Msg::Crash) => {
                    crashed = true;
                    break 'learn;
                }
                Some(Msg::Chunk(c)) => {
                    debug_assert_eq!((c.base_step, c.k), (base_step, k));
                    // Capture the pre-round actor masters while they are
                    // still the content of snapshot version `round`: if
                    // this round crosses a checkpoint boundary and runs
                    // updates, resume needs them to republish the lag-2
                    // window.
                    let due = store.is_some()
                        && run_state::ckpt_due(cfg.checkpoint_every, base_step, base_step + k);
                    let pre = if due { Some(agent.actor_flats()) } else { None };
                    replay.push_batch(k, &c.obs, &c.act, &c.rew, &c.next_obs, &done_buf[..k]);
                    // hand the consumed chunk straight back to the
                    // collector: its vectors get re-filled, not
                    // reallocated
                    queue.recycle(c);
                    // the exact strict-loop update accountant, shared
                    // code — update counts cannot drift between modes
                    let mut updated = false;
                    if base_step >= cfg.seed_steps {
                        // tidy-allow(determinism): telemetry-only timing.
                        let tu = Instant::now();
                        updated = sched.run_round(
                            cfg,
                            &mut agent,
                            &replay,
                            &mut rng,
                            &mut arena,
                            &mut grad_hist,
                            base_step,
                            k,
                        );
                        update_secs += tu.elapsed().as_secs_f64();
                    }
                    step = base_step + k;

                    // Republish before evaluating: eval is slow and the
                    // collector should not stall behind it.
                    // tidy-allow(determinism): telemetry-only timing.
                    let tp = Instant::now();
                    if updated {
                        last_snapshot = Arc::new(agent.policy());
                        snapshot_refreshes += 1;
                    }
                    slot.publish(round as u64 + 1, last_snapshot.clone());
                    if updated {
                        // clone + publish (lock + wakeup) — the full
                        // refresh cost on the learner's critical path
                        snapshot_publish_secs += tp.elapsed().as_secs_f64();
                    }
                    if faults.kill_due(step, KillPhase::Round) {
                        killed = true;
                        break 'learn;
                    }

                    if step % eval_every == 0 || step == cfg.steps {
                        let score = if agent.crashed || crashed {
                            0.0
                        } else {
                            evaluate(&mut agent, cfg, cfg.eval_episodes, cfg.seed ^ 0x5EED)
                        };
                        eval_curve.push((step * repeat) as f64, score);
                        if agent.crashed {
                            crashed = true;
                            break 'learn;
                        }
                        if faults.kill_due(step, KillPhase::Eval) {
                            killed = true;
                            break 'learn;
                        }
                    }

                    if due {
                        // The collector shipped its half of the state
                        // right behind this round's chunk (FIFO).
                        match queue.pop() {
                            Some(Msg::CkptState(blob)) => {
                                if let Some(st) = store.as_mut() {
                                    let payload = run_state::save_async(
                                        cfg,
                                        n,
                                        step,
                                        &rng,
                                        &blob,
                                        &replay,
                                        &agent,
                                        &sched,
                                        &eval_curve,
                                        &grad_hist,
                                        round + 1,
                                        if updated { pre.as_ref() } else { None },
                                    );
                                    st.save(step as u64, &payload)
                                        .unwrap_or_else(|e| panic!("{e:#}"));
                                }
                                if faults.kill_due(step, KillPhase::Ckpt) {
                                    killed = true;
                                    break 'learn;
                                }
                            }
                            Some(Msg::Crash) => {
                                crashed = true;
                                break 'learn;
                            }
                            _ => {
                                collector_died = true;
                                break 'learn;
                            }
                        }
                    }
                }
            }
        }

        // Unblock the collector whatever state it is in, then join.
        queue.stop();
        slot.stop();
        let secs = match handle.join() {
            Ok(secs) => secs,
            Err(e) => std::panic::resume_unwind(e),
        };
        // A normally-returning collector queues every scheduled round
        // (or a Crash) before closing, so an empty closed queue without
        // a panic payload is an invariant violation, not a timing case.
        assert!(!collector_died, "collector exited without delivering its rounds");
        secs
    });

    if crashed || agent.crashed {
        // paper: crashed runs are scored as 0 for the rest of training
        eval_curve.push((cfg.steps * repeat) as f64, 0.0);
    }
    let final_score = if crashed || agent.crashed { 0.0 } else { eval_curve.last_y() };
    TrainOutcome {
        cfg: cfg.clone(),
        eval_curve,
        final_score,
        crashed: crashed || agent.crashed,
        killed,
        grad_hist,
        wall_secs: t0.elapsed().as_secs_f64(),
        skipped_steps: sched.skipped,
        collect_steps_per_sec: if collect_secs > 0.0 { step as f64 / collect_secs } else { 0.0 },
        updates_per_sec: if update_secs > 0.0 {
            sched.updates_done as f64 / update_secs
        } else {
            0.0
        },
        updates: sched.updates_done,
        replay_fingerprint: replay_fingerprint_capped(&replay),
        snapshot_refreshes,
        snapshot_publish_secs,
        policy: Some(agent.policy()),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::train;
    use crate::coordinator::trainer::build_agent;

    fn quick_cfg() -> RunConfig {
        RunConfig {
            task: "pendulum_swingup".into(),
            preset: "fp32".into(),
            steps: 120,
            seed_steps: 40,
            batch: 16,
            hidden: 24,
            eval_every: 60,
            eval_episodes: 1,
            num_envs: 4,
            sync_mode: "async".into(),
            ..Default::default()
        }
    }

    #[test]
    fn round_schedule_matches_strict_round_splitting() {
        // the schedule must reproduce the strict loop's online round
        // computation: cover every step once, never straddle the seed
        // phase or an eval boundary, never exceed num_envs
        for (steps, seed_steps, eval_every, n) in
            [(120, 40, 60, 4), (100, 30, 30, 7), (64, 16, 64, 1), (10, 20, 4, 3)]
        {
            let cfg = RunConfig {
                steps,
                seed_steps,
                eval_every,
                num_envs: n,
                ..quick_cfg()
            };
            let sched: Vec<(usize, usize, usize)> = rounds(&cfg, n).collect();
            let mut step = 0usize;
            for (i, &(round, base, k)) in sched.iter().enumerate() {
                assert_eq!(round, i, "round indices are sequential");
                assert_eq!(base, step, "rounds are contiguous");
                assert!((1..=n).contains(&k));
                assert!(
                    !(base < seed_steps && base + k > seed_steps),
                    "round must not straddle the seed phase"
                );
                assert_eq!(
                    (base / eval_every),
                    ((base + k - 1) / eval_every),
                    "round must not straddle an eval boundary"
                );
                step += k;
            }
            assert_eq!(step, steps, "schedule covers exactly cfg.steps");
        }
    }

    #[test]
    fn async_poisoned_actor_crashes_scores_zero_and_pads_curve() {
        // the paper's crash accounting must survive the thread hop: a
        // NaN actor crashes in the *collector*, the learner sees the
        // crash message, scores 0 and pads the curve to full length
        let cfg = quick_cfg();
        let venv = VecEnv::new(&cfg, cfg.num_envs).unwrap();
        let mut agent = build_agent(&cfg, venv.obs_len(), venv.act_dim());
        for prm in agent.actor.params_mut() {
            for w in prm.w.iter_mut() {
                *w = f32::NAN;
            }
        }
        let out = train_agent_async(&cfg, venv, agent);
        assert!(out.crashed, "poisoned actor must crash the async run");
        assert_eq!(out.final_score, 0.0);
        let repeat = crate::envs::action_repeat(&cfg.task);
        let last = out.eval_curve.points.last().unwrap();
        assert_eq!(last.0, (cfg.steps * repeat) as f64, "curve padded to full length");
        assert_eq!(last.1, 0.0);
        // crash fires at the first policy round (step 40 < eval 60):
        // only the padding point exists
        assert_eq!(out.eval_curve.points.len(), 1);
        assert_eq!(out.updates, 0, "no update ran before the crash");
    }

    #[test]
    fn chunk_recycling_reuses_capacity_and_is_bounded() {
        let q = Queue::new(2);
        // nothing recycled yet: a fresh empty chunk
        let mut c = q.take_spare();
        assert_eq!(c.obs.capacity(), 0);
        c.fill(0, 2, &[1.0; 8], &[0.5; 2], &[0.1; 2], &[2.0; 8]);
        let obs_ptr = c.obs.as_ptr();
        let obs_cap = c.obs.capacity();
        q.recycle(c);
        // the recycled chunk comes back with its buffers intact...
        let mut c2 = q.take_spare();
        assert_eq!(c2.obs.as_ptr(), obs_ptr);
        // ...and re-filling a same-size round does not reallocate
        c2.fill(4, 2, &[3.0; 8], &[0.2; 2], &[0.3; 2], &[4.0; 8]);
        assert_eq!(c2.obs.capacity(), obs_cap);
        assert_eq!(c2.obs.as_ptr(), obs_ptr);
        assert_eq!(c2.obs, vec![3.0; 8]);
        assert_eq!(c2.base_step, 4);
        q.recycle(c2);
        // the spare stack is bounded by cap + 1 = 3
        for _ in 0..10 {
            q.recycle(Chunk::default());
        }
        assert!(q.spare.lock().unwrap().len() <= 3);
    }

    #[test]
    fn async_kill_and_resume_matches_uninterrupted_run() {
        // the async twin of the strict resume contract, which is the
        // harder half: resume must also reconstruct the lag-2 snapshot
        // window (versions round-1 and round, the former rebuilt from
        // the checkpointed pre-round actor flats), so the collector's
        // lagged fetches see exactly the snapshots the uninterrupted
        // run would have served
        let base = train(&quick_cfg());
        // with num_envs=4 / every=25 the due rounds end at steps 28, 52,
        // 76, 100 — all three kill points resume from a post-seed
        // generation whose round ran updates (pre_actor = Some path)
        for (tag, faults) in
            [("round", "kill@80:round"), ("eval", "kill@60:eval"), ("ckpt", "kill@52:ckpt")]
        {
            let dir = std::env::temp_dir()
                .join(format!("lprl_async_{tag}_{}", std::process::id()));
            let _ = std::fs::remove_dir_all(&dir);
            let mut kill_cfg = quick_cfg();
            kill_cfg.out_dir = dir.to_string_lossy().into_owned();
            kill_cfg.checkpoint_every = 25;
            kill_cfg.faults = faults.into();
            let killed = train(&kill_cfg);
            assert!(killed.killed, "{faults} must stop the async run early");
            assert!(!killed.crashed);

            let mut res_cfg = quick_cfg();
            res_cfg.resume_from = dir.join("ckpt").to_string_lossy().into_owned();
            let resumed = train(&res_cfg);
            assert!(!resumed.killed && !resumed.crashed);
            assert_eq!(
                resumed.eval_curve.points, base.eval_curve.points,
                "{faults}: resumed async eval curve must match the uninterrupted run"
            );
            assert_eq!(
                resumed.replay_fingerprint, base.replay_fingerprint,
                "{faults}: replay contents must match"
            );
            assert_eq!(resumed.updates, base.updates);
            let probe = |o: &TrainOutcome| {
                let p = o.policy.as_ref().unwrap();
                let obs: Vec<f32> =
                    (0..p.obs_len()).map(|i| ((i as f32) * 0.37).sin()).collect();
                let t = p.obs_tensor(&obs, 1);
                p.act_batch(&t, crate::sac::ActMode::Deterministic)
                    .data
                    .iter()
                    .map(|v| v.to_bits())
                    .collect::<Vec<u32>>()
            };
            assert_eq!(
                probe(&resumed),
                probe(&base),
                "{faults}: final params must match bitwise"
            );
            let _ = std::fs::remove_dir_all(&dir);
        }
    }

    #[test]
    fn async_short_run_completes_with_throughput_stats() {
        let out = train(&quick_cfg());
        assert!(!out.crashed);
        assert!(!out.eval_curve.points.is_empty());
        assert!(out.collect_steps_per_sec > 0.0);
        assert!(out.updates_per_sec > 0.0);
        assert!(out.snapshot_refreshes > 0, "learner must republish snapshots");
        assert!(out.grad_hist.total() > 0, "grad probe must fire in async mode too");
    }
}
