//! The training loop, restructured as an explicit collector/learner
//! architecture over vectorized environments.
//!
//! One training round = **collect → update → eval**:
//!
//! * **collect** — one shared (batched) policy forward produces an
//!   action row per env stream; all `num_envs` streams advance one agent
//!   step in lockstep and their transitions enter the replay buffer as a
//!   chunk (`ReplayBuffer::push_batch`).
//! * **update** — one gradient step per collected transition (the SAC
//!   1-update-per-transition schedule is preserved exactly: `N`
//!   transitions per shared forward, `N` updates), sampling through the
//!   allocation-free `ReplayBuffer::sample_into` path.
//! * **eval** — periodic deterministic evaluation with an immutable
//!   [`Policy`] snapshot, plus the paper's crash accounting (a
//!   non-finite action scores the run 0 from then on).
//!
//! Rounds are split at the seed-phase and eval boundaries, so every
//! round is phase-pure and evals land on the same agent-step grid for
//! every `num_envs`.
//!
//! Determinism contract: runs are fully deterministic in `cfg.seed` for
//! any `num_envs`. With `num_envs = 1` the loop degenerates to the
//! original single-env trainer draw for draw — the shared trainer
//! stream (`seed_stream(seed, 7)`) serves resets, seed-phase actions
//! and replay sampling, and exploration noise comes from the agent's
//! own stream — so eval curves are bitwise identical to the
//! pre-vectorization trainer. With `num_envs > 1` each env stream owns
//! an independent `Pcg64` stream for its resets, seed-phase actions and
//! exploration noise.

use super::{run_state, EPISODE_ENV_STEPS};
use crate::ckpt::{FaultPlan, KillPhase};
use crate::config::RunConfig;
use crate::envs::{sanitize_action, VecEnv};
use crate::nn::Tensor;
use crate::replay::{ReplayBuffer, RoundArena, Storage};
use crate::rngs::Pcg64;
use crate::sac::{ActMode, Policy, SacAgent, SacConfig};
use crate::telemetry::{LogHistogram, Series};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::time::Instant;

/// Stream ids on `cfg.seed`: 7 is the legacy shared trainer stream;
/// per-env streams for `num_envs > 1` (and every async-mode stream)
/// start here.
pub(super) const ENV_STREAM_BASE: u64 = 0x1000;

/// Result of one training run.
pub struct TrainOutcome {
    pub cfg: RunConfig,
    /// Evaluation curve: (agent env-steps × action-repeat, mean return).
    pub eval_curve: Series,
    /// Mean return of the final evaluation (0 if crashed).
    pub final_score: f64,
    pub crashed: bool,
    /// True when a `faults` kill point stopped the run early (the
    /// fault-injection harness's simulated SIGKILL — distinct from
    /// `crashed`, the paper's non-finite-action condition). A killed
    /// outcome reflects the run state at the kill boundary; resuming
    /// from the surviving checkpoint store must reproduce the no-kill
    /// run bitwise (see `tests/ckpt_resume.rs`).
    pub killed: bool,
    /// |gradient| histogram sampled at a few updates (Figure 6).
    pub grad_hist: LogHistogram,
    pub wall_secs: f64,
    /// Total optimizer steps skipped due to non-finite gradients.
    pub skipped_steps: u64,
    /// Collection throughput: agent transitions gathered per second of
    /// collect-stage wall time (action selection + env stepping +
    /// replay pushes).
    pub collect_steps_per_sec: f64,
    /// Learner throughput: gradient updates per second of update-stage
    /// wall time (replay sampling + SAC update).
    pub updates_per_sec: f64,
    /// Total gradient updates executed. Structural under the
    /// 1-update-per-transition schedule: identical for every `num_envs`
    /// *and* every `sync_mode` given the same `(steps, seed_steps,
    /// batch)` — the contract the async relaxed-determinism tests pin.
    pub updates: u64,
    /// Order-independent multiset hash of the final replay contents
    /// ([`ReplayBuffer::fingerprint`]): the observable for "same
    /// transition multiset" claims across interleaves. `0` when the
    /// buffer exceeds [`FINGERPRINT_MAX_FLOATS`] (hashing a paper-scale
    /// pixel replay would add minutes of dead time to every run) — the
    /// contract tests all use small buffers.
    pub replay_fingerprint: u64,
    /// Async mode: number of fresh policy snapshots published to the
    /// collector (0 in strict mode, where the collector reads live
    /// weights).
    pub snapshot_refreshes: u64,
    /// Async mode: total wall time spent cloning + publishing those
    /// snapshots (`snapshot_publish_secs / snapshot_refreshes` = mean
    /// refresh latency).
    pub snapshot_publish_secs: f64,
    /// Immutable snapshot of the final trained policy — the artifact
    /// the serve layer consumes. Always `Some` from [`train`]; holds a
    /// full copy of the actor (and encoder) weights, so [`run_many`]
    /// (experiment grids that keep every outcome alive and only read
    /// the scalar results) clears it to keep grid memory flat.
    pub policy: Option<Policy>,
}

/// Round size at `step`: up to one transition per env stream, clipped
/// so a round never straddles the seed phase or an eval boundary. The
/// single definition of the round-splitting rule — the strict loop
/// calls it online and the async pipeline walks it through
/// `pipeline`'s lazy schedule iterator, so the eval grid and the
/// update accountant are `sync_mode`-invariant by construction.
pub(super) fn round_len(cfg: &RunConfig, n: usize, step: usize) -> usize {
    let eval_every = cfg.eval_every.max(1);
    let mut k = n.min(cfg.steps - step);
    if step < cfg.seed_steps {
        k = k.min(cfg.seed_steps - step);
    }
    k.min((step / eval_every + 1) * eval_every - step)
}

/// The per-round learner body shared by the strict and async loops:
/// warm-up gate, grad-probe schedule, replay sampling and SAC update
/// for the `k` transitions of the round starting at `base_step`. One
/// definition ⇒ update counts (and probe points) cannot drift between
/// `sync_mode`s — the invariance the async contract tests pin.
pub(super) struct UpdateSchedule {
    /// Probe points (Figure 6), consumed front to back (no per-step scan).
    probe_at: Vec<usize>,
    next_probe: usize,
    pub(super) updates_done: u64,
    /// Skipped-optimizer-step count from the most recent update.
    pub(super) skipped: u64,
}

impl UpdateSchedule {
    pub(super) fn new(cfg: &RunConfig) -> Self {
        UpdateSchedule {
            probe_at: (1..=3).map(|i| cfg.steps * i / 4).collect(),
            next_probe: 0,
            updates_done: 0,
            skipped: 0,
        }
    }

    /// Serialize the schedule's mutable counters (checkpoint path). The
    /// probe points themselves are rebuilt from the config by
    /// [`UpdateSchedule::new`]; only the cursor and the tallies move.
    pub(super) fn ckpt_write(&self, enc: &mut crate::ckpt::Enc) {
        enc.u64(self.next_probe as u64);
        enc.u64(self.updates_done);
        enc.u64(self.skipped);
    }

    /// Restore a [`UpdateSchedule::ckpt_write`] snapshot.
    pub(super) fn ckpt_read(&mut self, dec: &mut crate::ckpt::Dec) -> anyhow::Result<()> {
        let next_probe = dec.usize()?;
        anyhow::ensure!(
            next_probe <= self.probe_at.len(),
            "checkpoint probe cursor {next_probe} exceeds the {} probe points this run has",
            self.probe_at.len()
        );
        self.next_probe = next_probe;
        self.updates_done = dec.u64()?;
        self.skipped = dec.u64()?;
        Ok(())
    }

    /// One gradient step per transition of the round; returns whether
    /// any update ran (the async learner republishes its snapshot only
    /// then).
    ///
    /// The round runs in two phases. First the **plan** pass replays the
    /// legacy per-transition accounting (warm-up gate, probe-point
    /// consumption) without touching any state, so update counts and
    /// probe placement are byte-for-byte the old schedule. Then all of
    /// the round's minibatches are pre-sampled into the reusable arena
    /// ([`ReplayBuffer::sample_round_into`] — replay is frozen during
    /// the update phase and the replay-sampling stream is independent of
    /// the agent's noise stream, so this reordering is bitwise-neutral)
    /// and handed to `SacAgent::update_round`, which fuses target-side
    /// forwards across consecutive updates where the target weights are
    /// shared. A probed update runs as its own one-update round so the
    /// probe captures exactly that update's gradients, as before.
    #[allow(clippy::too_many_arguments)]
    pub(super) fn run_round(
        &mut self,
        cfg: &RunConfig,
        agent: &mut SacAgent,
        replay: &ReplayBuffer,
        rng: &mut Pcg64,
        arena: &mut RoundArena,
        grad_hist: &mut LogHistogram,
        base_step: usize,
        k: usize,
    ) -> bool {
        // -- plan: which transitions update, and where probes land ------
        let mut n_updates = 0usize;
        // a round can contain several probe points (tiny steps with wide
        // rounds); each probed update runs as its own segment below.
        // tidy-allow(alloc): `Vec::new` is capacity-0 (no heap touch);
        // probe-free rounds (all but ~3 per run) never push into it
        let mut probe_updates: Vec<usize> = Vec::new();
        for j in 0..k {
            let s = base_step + j;
            // warm-up gate, per transition so update counts stay
            // num_envs-invariant: the update for transition s runs only
            // once the per-step trainer would have had >= batch
            // transitions (it had min(s + 1, len) at step s)
            if (s + 1).min(replay.len()) < cfg.batch {
                continue;
            }
            // advance past probe points that never saw an update
            // (seed phase / replay warm-up)
            while self.next_probe < self.probe_at.len() && self.probe_at[self.next_probe] < s {
                self.next_probe += 1;
            }
            if self.next_probe < self.probe_at.len() && self.probe_at[self.next_probe] == s {
                probe_updates.push(n_updates);
                self.next_probe += 1;
            }
            n_updates += 1;
        }
        if n_updates == 0 {
            return false;
        }

        // -- sample the whole round into the arena, then update --------
        let aug_pad = if cfg.pixels { Some(2) } else { None };
        replay.sample_round_into(n_updates, cfg.batch, aug_pad, rng, arena);
        let batches = arena.batches();
        let mut run_seg = |agent: &mut SacAgent, lo: usize, hi: usize| {
            if lo < hi {
                let stats = agent.update_round(&batches[lo..hi]);
                self.skipped = stats.skipped_steps;
            }
        };
        let mut lo = 0usize;
        for &pu in &probe_updates {
            run_seg(agent, lo, pu);
            // tidy-allow(alloc): probe segments only (~3 per run), not the
            // steady-state update loop
            agent.grad_probe = Some(Vec::new());
            run_seg(agent, pu, pu + 1);
            if let Some(probe) = agent.grad_probe.take() {
                grad_hist.record_all(&probe);
            }
            lo = pu + 1;
        }
        run_seg(agent, lo, n_updates);
        self.updates_done += n_updates as u64;
        true
    }
}

/// Upper bound (in stored f32 values, ~64 MB as f32) up to which
/// [`TrainOutcome::replay_fingerprint`] is computed; larger buffers
/// report 0 instead of stalling the end of the run on a byte-wise hash.
pub const FINGERPRINT_MAX_FLOATS: usize = 1 << 24;

/// [`ReplayBuffer::fingerprint`] behind the size cap above.
pub(super) fn replay_fingerprint_capped(replay: &ReplayBuffer) -> u64 {
    if replay.stored_floats() <= FINGERPRINT_MAX_FLOATS {
        replay.fingerprint()
    } else {
        0
    }
}

pub(super) fn build_agent(cfg: &RunConfig, obs_dim: usize, act_dim: usize) -> SacAgent {
    let (prec, methods) = cfg
        .preset()
        .unwrap_or_else(|| panic!("unknown preset {}", cfg.preset));
    let mut sac_cfg = if cfg.pixels {
        SacConfig::pixels(cfg.feature_dim, act_dim, cfg.hidden)
    } else {
        SacConfig::states(obs_dim, act_dim, cfg.hidden)
    };
    if cfg.lr > 0.0 {
        sac_cfg.lr = cfg.lr;
    }
    if cfg.gamma > 0.0 {
        sac_cfg.gamma = cfg.gamma;
    }
    if cfg.tau > 0.0 {
        sac_cfg.tau = cfg.tau;
    }
    if cfg.init_temp > 0.0 {
        sac_cfg.init_temperature = cfg.init_temp;
    }
    if cfg.min_log_sig != 0.0 {
        sac_cfg.log_sig_lo = cfg.min_log_sig;
    }
    let mut agent = if cfg.pixels {
        SacAgent::new_pixels(
            sac_cfg,
            methods,
            prec,
            cfg.seed,
            cfg.frame_stack * 3,
            cfg.image_size,
            cfg.filters,
        )
    } else {
        SacAgent::new(sac_cfg, methods, prec, cfg.seed)
    };
    if let Some(fmt) = cfg.half_storage() {
        agent.set_half_storage(fmt);
    }
    agent
}


/// Shared lockstep evaluation core: run the env streams `ids[i]` (each
/// seeded as `seed_stream(eval_seed, 1000 + ids[i])`) for one fixed
/// 1000-env-step episode under the deterministic policy, all advancing
/// with one batched forward per agent step. Returns per-episode raw
/// returns, or `None` if the policy produced a non-finite action (the
/// paper's crash condition).
fn eval_lockstep(policy: &Policy, cfg: &RunConfig, ids: &[u64], eval_seed: u64) -> Option<Vec<f64>> {
    let mut venv = VecEnv::new(cfg, ids.len()).unwrap_or_else(|e| panic!("{e}"));
    let steps = EPISODE_ENV_STEPS / venv.action_repeat();
    let obs_len = venv.obs_len();
    let mut obs_flat = vec![0.0f32; ids.len() * obs_len];
    for (i, &id) in ids.iter().enumerate() {
        let mut rng = Pcg64::seed_stream(eval_seed, 1000 + id);
        venv.reset_into(i, &mut rng, &mut obs_flat[i * obs_len..(i + 1) * obs_len]);
    }
    let mut totals = vec![0.0f64; ids.len()];
    let mut stage = Tensor::default();
    for _ in 0..steps {
        let t = policy.stage_obs(&mut stage, &obs_flat, ids.len());
        let mut acts = policy.act_batch(t, ActMode::Deterministic);
        if !venv.step_lockstep(&mut acts, &mut obs_flat, &mut totals) {
            return None; // crash ⇒ the paper scores the run as 0
        }
    }
    Some(totals)
}

/// Run `episodes` deterministic evaluation episodes one at a time with
/// an immutable [`Policy`] snapshot (batch-1 forwards — the reference
/// path). Returns `None` if the policy produced a non-finite action
/// (the paper's crash condition), otherwise the mean return (sum of raw
/// env rewards over the 1000-env-step episode).
pub fn evaluate_policy(
    policy: &Policy,
    cfg: &RunConfig,
    episodes: usize,
    eval_seed: u64,
) -> Option<f64> {
    if episodes == 0 {
        return Some(0.0); // same degenerate-input answer as the batched path
    }
    let mut totals = Vec::with_capacity(episodes);
    for ep in 0..episodes {
        totals.extend(eval_lockstep(policy, cfg, &[ep as u64], eval_seed)?);
    }
    Some(totals.iter().sum::<f64>() / episodes as f64)
}

/// Same schedule as [`evaluate_policy`], but every episode advances in
/// lockstep with ONE batched forward per agent step (episodes share the
/// GEMMs). Bitwise identical to the looped path: episode RNG streams
/// are untouched, the GEMM backend is batch-size-invariant per row, and
/// per-episode returns are accumulated separately and reduced in the
/// same order. Fixed-length dm_control-style episodes make lockstep
/// exact (no early termination).
pub fn evaluate_policy_batched(
    policy: &Policy,
    cfg: &RunConfig,
    episodes: usize,
    eval_seed: u64,
) -> Option<f64> {
    if episodes == 0 {
        return Some(0.0);
    }
    let ids: Vec<u64> = (0..episodes as u64).collect();
    let totals = eval_lockstep(policy, cfg, &ids, eval_seed)?;
    Some(totals.iter().sum::<f64>() / episodes as f64)
}

/// Trainer-internal eval: snapshot the agent's policy, run the batched
/// evaluator, translate a crash into the agent's crash flag.
pub(super) fn evaluate(agent: &mut SacAgent, cfg: &RunConfig, episodes: usize, eval_seed: u64) -> f64 {
    let policy = agent.policy();
    match evaluate_policy_batched(&policy, cfg, episodes, eval_seed) {
        Some(score) => score,
        None => {
            agent.crashed = true;
            0.0
        }
    }
}

/// Train one agent per `cfg`; fully deterministic in `cfg.seed`.
///
/// Dispatches on `cfg.sync_mode`: `"strict"` (default) runs the
/// single-thread collect → update → eval loop below; `"async"` runs the
/// pipelined collector/learner in [`super::pipeline`]. Invalid configs
/// (unknown task) panic with the validation message — call
/// [`RunConfig::validate`] first to get it as an `Err`.
pub fn train(cfg: &RunConfig) -> TrainOutcome {
    let venv =
        VecEnv::new(cfg, cfg.num_envs.max(1)).unwrap_or_else(|e| panic!("{e}"));
    let agent = build_agent(cfg, venv.obs_len(), venv.act_dim());
    if cfg.sync_mode == "async" {
        super::pipeline::train_agent_async(cfg, venv, agent)
    } else {
        train_agent(cfg, venv, agent)
    }
}

/// The collector/learner loop over a pre-built agent — the seam the
/// crash-path tests use to inject poisoned weights.
fn train_agent(cfg: &RunConfig, mut venv: VecEnv, mut agent: SacAgent) -> TrainOutcome {
    // tidy-allow(determinism): wall-clock feeds throughput telemetry
    // only — no training decision reads it.
    let t0 = Instant::now();
    let n = venv.num_envs();
    let repeat = venv.action_repeat();
    let obs_len = venv.obs_len();
    let act_dim = venv.act_dim();
    let eval_every = cfg.eval_every.max(1);
    let mut rng = Pcg64::seed_stream(cfg.seed, 7);
    // Per-env streams (resets + seed actions + exploration noise) for
    // n > 1. n == 1 keeps the legacy layout — shared `rng` plus the
    // agent's own noise stream — for bitwise compatibility with the
    // original single-env trainer (see the module docs).
    let mut env_rngs: Vec<Pcg64> = if n > 1 {
        (0..n).map(|i| Pcg64::seed_stream(cfg.seed, ENV_STREAM_BASE + i as u64)).collect()
    } else {
        Vec::new()
    };

    let mut obs_flat = vec![0.0f32; n * obs_len];
    for i in 0..n {
        let r = if n == 1 { &mut rng } else { &mut env_rngs[i] };
        venv.reset_into(i, r, &mut obs_flat[i * obs_len..(i + 1) * obs_len]);
    }
    let storage = cfg.replay_storage(agent.compute.is_low());
    let mut replay = ReplayBuffer::new(cfg.replay_capacity, venv.obs_shape(), act_dim, storage);

    let mut eval_curve = Series::new(format!("{}:{}", cfg.task, cfg.preset));
    let mut grad_hist = LogHistogram::new(-12, 4, 2);
    let mut sched = UpdateSchedule::new(cfg);

    let episode_steps = EPISODE_ENV_STEPS / repeat;
    let mut ep_step = vec![0usize; n];
    let mut crashed = false;

    // collector staging buffers + the learner's reusable round arena
    let mut next_flat = vec![0.0f32; n * obs_len];
    let mut rew_buf = vec![0.0f32; n];
    let done_buf = vec![false; n]; // dm_control time limits are not true terminals
    let mut arena = RoundArena::default();
    let mut obs_stage = Tensor::default();

    let mut collect_secs = 0.0f64;
    let mut update_secs = 0.0f64;

    // -- checkpoint / resume / fault-injection wiring ------------------
    // `validate()` has already vetted the spec when the config came
    // through the CLI; the test seam calls `train_agent` directly.
    let mut faults =
        FaultPlan::parse(&cfg.faults).unwrap_or_else(|e| panic!("bad faults spec: {e}"));
    let mut store = run_state::open_store(cfg);
    if let Some(st) = store.as_mut() {
        st.arm_torn(faults.torn.take());
    }
    let mut killed = false;
    let mut step = 0usize;
    if let Some(st) = store.as_ref() {
        if let Some((_, payload)) = run_state::load_resume(cfg, st) {
            step = run_state::resume_strict(
                &payload,
                cfg,
                n,
                &mut rng,
                &mut env_rngs,
                &mut obs_flat,
                &mut ep_step,
                &mut venv,
                &mut replay,
                &mut agent,
                &mut sched,
                &mut eval_curve,
                &mut grad_hist,
            )
            .unwrap_or_else(|e| panic!("resume_from {}: {e:#}", cfg.resume_from));
        }
    }

    'train: while step < cfg.steps {
        let k = round_len(cfg, n, step);

        // -- collect: one shared forward drives k env streams ----------
        // tidy-allow(determinism): telemetry-only timing.
        let tc = Instant::now();
        let mut acts = if step < cfg.seed_steps {
            let mut t = Tensor::zeros(&[k, act_dim]);
            for i in 0..k {
                let r = if n == 1 { &mut rng } else { &mut env_rngs[i] };
                for v in t.row_mut(i) {
                    *v = r.uniform_in(-1.0, 1.0);
                }
            }
            t
        } else {
            let obs_t = obs_stage.stage_rows(&obs_flat[..k * obs_len], k, venv.obs_shape());
            let a = if n == 1 {
                agent.act_batch(obs_t, true)
            } else {
                agent.act_batch_envs(obs_t, &mut env_rngs[..k])
            };
            match a {
                Some(a) => a,
                None => {
                    crashed = true;
                    collect_secs += tc.elapsed().as_secs_f64();
                    break 'train;
                }
            }
        };
        for i in 0..k {
            if !sanitize_action(acts.row_mut(i)) {
                crashed = true;
            }
        }
        if crashed {
            collect_secs += tc.elapsed().as_secs_f64();
            break 'train;
        }
        for i in 0..k {
            rew_buf[i] =
                venv.step_into(i, acts.row(i), &mut next_flat[i * obs_len..(i + 1) * obs_len]);
            ep_step[i] += 1;
        }
        replay.push_batch(
            k,
            &obs_flat[..k * obs_len],
            &acts.data,
            &rew_buf[..k],
            &next_flat[..k * obs_len],
            &done_buf[..k],
        );
        obs_flat[..k * obs_len].copy_from_slice(&next_flat[..k * obs_len]);
        for i in 0..k {
            if ep_step[i] >= episode_steps {
                let r = if n == 1 { &mut rng } else { &mut env_rngs[i] };
                venv.reset_into(i, r, &mut obs_flat[i * obs_len..(i + 1) * obs_len]);
                ep_step[i] = 0;
            }
        }
        collect_secs += tc.elapsed().as_secs_f64();

        // -- update: one gradient step per collected transition --------
        if step >= cfg.seed_steps {
            // tidy-allow(determinism): telemetry-only timing.
            let tu = Instant::now();
            sched.run_round(
                cfg, &mut agent, &replay, &mut rng, &mut arena, &mut grad_hist, step, k,
            );
            update_secs += tu.elapsed().as_secs_f64();
        }
        step += k;
        if faults.kill_due(step, KillPhase::Round) {
            killed = true;
            break 'train;
        }

        // -- eval --------------------------------------------------------
        if step % eval_every == 0 || step == cfg.steps {
            let score = if agent.crashed || crashed {
                0.0
            } else {
                evaluate(&mut agent, cfg, cfg.eval_episodes, cfg.seed ^ 0x5EED)
            };
            eval_curve.push((step * repeat) as f64, score);
            if agent.crashed {
                crashed = true;
                break 'train;
            }
            if faults.kill_due(step, KillPhase::Eval) {
                killed = true;
                break 'train;
            }
        }

        // -- checkpoint --------------------------------------------------
        if run_state::ckpt_due(cfg.checkpoint_every, step - k, step) && !crashed {
            if let Some(st) = store.as_mut() {
                let payload = run_state::save_strict(
                    cfg, n, step, &rng, &env_rngs, &obs_flat, &ep_step, &venv, &replay,
                    &agent, &sched, &eval_curve, &grad_hist,
                );
                st.save(step as u64, &payload).unwrap_or_else(|e| panic!("{e:#}"));
            }
            if faults.kill_due(step, KillPhase::Ckpt) {
                killed = true;
                break 'train;
            }
        }
    }

    if crashed || agent.crashed {
        // paper: crashed runs are scored as 0 for the rest of training
        eval_curve.push((cfg.steps * repeat) as f64, 0.0);
    }
    let final_score = if crashed || agent.crashed { 0.0 } else { eval_curve.last_y() };
    TrainOutcome {
        cfg: cfg.clone(),
        eval_curve,
        final_score,
        crashed: crashed || agent.crashed,
        killed,
        grad_hist,
        wall_secs: t0.elapsed().as_secs_f64(),
        skipped_steps: sched.skipped,
        collect_steps_per_sec: if collect_secs > 0.0 { step as f64 / collect_secs } else { 0.0 },
        updates_per_sec: if update_secs > 0.0 {
            sched.updates_done as f64 / update_secs
        } else {
            0.0
        },
        updates: sched.updates_done,
        replay_fingerprint: replay_fingerprint_capped(&replay),
        snapshot_refreshes: 0,
        snapshot_publish_secs: 0.0,
        policy: Some(agent.policy()),
    }
}

/// Train many configurations in parallel across OS threads (one run per
/// thread, capped at the host parallelism). Results keep input order.
///
/// Each worker claims config indices from a shared counter and keeps
/// its finished outcomes in a thread-local vector, merged once after
/// the joins — no shared lock anywhere on the result path (the previous
/// implementation funneled every finishing run through one
/// `Mutex<Vec<Option<_>>>`, serializing grids exactly when parallel
/// runs finish back-to-back).
pub fn run_many(cfgs: &[RunConfig]) -> Vec<TrainOutcome> {
    let n = cfgs.len();
    let mut results: Vec<Option<TrainOutcome>> = (0..n).map(|_| None).collect();
    let next = AtomicUsize::new(0);
    // tidy-allow(determinism): machine shape only sizes the worker count
    // for independent runs; every run's result is seed-determined and
    // written back to its own slot, so ordering cannot leak in.
    let workers = std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1).min(n.max(1));
    // tidy-allow(determinism): sanctioned structured-concurrency seam for
    // fully independent grid runs — see the worker-count note above.
    std::thread::scope(|s| {
        let handles: Vec<_> = (0..workers)
            .map(|_| {
                s.spawn(|| {
                    let mut mine = Vec::new();
                    loop {
                        let i = next.fetch_add(1, Ordering::Relaxed);
                        if i >= n {
                            break;
                        }
                        let mut out = train(&cfgs[i]);
                        // grids only read scalars/curves; don't pin every
                        // run's weight snapshot for the whole grid
                        out.policy = None;
                        mine.push((i, out));
                    }
                    mine
                })
            })
            .collect();
        for h in handles {
            match h.join() {
                Ok(mine) => {
                    for (i, out) in mine {
                        results[i] = Some(out);
                    }
                }
                // surface the worker's original panic payload, exactly
                // as the pre-refactor scope-propagated panic did
                Err(e) => std::panic::resume_unwind(e),
            }
        }
    });
    // tidy-allow(panic): every index is filled unless a worker panicked,
    // and a worker panic has already been re-raised above.
    results.into_iter().map(|o| o.expect("worker died")).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quick_cfg() -> RunConfig {
        RunConfig {
            task: "pendulum_swingup".into(),
            preset: "fp32".into(),
            steps: 120,
            seed_steps: 40,
            batch: 16,
            hidden: 24,
            eval_every: 60,
            eval_episodes: 1,
            ..Default::default()
        }
    }

    #[test]
    fn fp32_short_run_completes() {
        let out = train(&quick_cfg());
        assert!(!out.crashed);
        assert!(!out.eval_curve.points.is_empty());
        assert!(out.final_score >= 0.0);
        assert!(out.grad_hist.total() > 0, "grad probe must fire");
        assert!(out.collect_steps_per_sec > 0.0);
        assert!(out.updates_per_sec > 0.0);
    }

    #[test]
    fn fp16_ours_short_run_completes() {
        let mut cfg = quick_cfg();
        cfg.preset = "fp16_ours".into();
        let out = train(&cfg);
        assert!(!out.crashed, "fp16+ours must not crash");
    }

    #[test]
    fn deterministic_given_seed() {
        let cfg = quick_cfg();
        let a = train(&cfg);
        let b = train(&cfg);
        assert_eq!(a.eval_curve.points, b.eval_curve.points);
        let mut cfg2 = cfg.clone();
        cfg2.seed = 1;
        let c = train(&cfg2);
        assert_ne!(a.eval_curve.points, c.eval_curve.points);
    }

    #[test]
    fn vectorized_runs_are_deterministic() {
        // two num_envs=4 runs must match exactly; a different seed must not
        let mut cfg = quick_cfg();
        cfg.num_envs = 4;
        let a = train(&cfg);
        let b = train(&cfg);
        assert!(!a.crashed);
        assert_eq!(a.eval_curve.points, b.eval_curve.points, "N=4 must be deterministic");
        let mut cfg2 = cfg.clone();
        cfg2.seed = 3;
        let c = train(&cfg2);
        assert_ne!(a.eval_curve.points, c.eval_curve.points);
    }

    #[test]
    fn vectorized_eval_grid_matches_single_env() {
        // rounds split at eval boundaries, so the eval x-grid (and the
        // number of updates implied by 1-update-per-transition) is
        // identical for every num_envs
        let mut c1 = quick_cfg();
        c1.preset = "fp16_ours".into();
        let mut c4 = c1.clone();
        c4.num_envs = 4;
        let a = train(&c1);
        let b = train(&c4);
        let xs = |o: &TrainOutcome| o.eval_curve.points.iter().map(|p| p.0).collect::<Vec<_>>();
        assert_eq!(xs(&a), xs(&b), "same eval step grid regardless of num_envs");
    }

    #[test]
    fn vectorized_num_envs_not_dividing_steps() {
        // steps % num_envs != 0 and eval boundaries mid-round: the final
        // partial round must still stop exactly at cfg.steps
        let mut cfg = quick_cfg();
        cfg.num_envs = 7;
        cfg.steps = 100;
        cfg.eval_every = 30;
        let out = train(&cfg);
        assert!(!out.crashed);
        let repeat = crate::envs::action_repeat(&cfg.task);
        assert_eq!(
            out.eval_curve.points.last().unwrap().0,
            (cfg.steps * repeat) as f64,
            "final eval lands exactly on cfg.steps"
        );
    }

    #[test]
    fn crash_mid_training_scores_zero_and_pads_curve() {
        // the paper's crash accounting: a policy emitting a non-finite
        // action mid-training scores 0 from then on and the eval curve
        // is padded out to the full training length
        let cfg = quick_cfg();
        let venv = VecEnv::new(&cfg, 1).unwrap();
        let mut agent = build_agent(&cfg, venv.obs_len(), venv.act_dim());
        for prm in agent.actor.params_mut() {
            for w in prm.w.iter_mut() {
                *w = f32::NAN;
            }
        }
        let out = train_agent(&cfg, venv, agent);
        assert!(out.crashed, "poisoned actor must crash the run");
        assert_eq!(out.final_score, 0.0);
        let repeat = crate::envs::action_repeat(&cfg.task);
        let last = out.eval_curve.points.last().unwrap();
        assert_eq!(last.0, (cfg.steps * repeat) as f64, "curve padded to full length");
        assert_eq!(last.1, 0.0, "crashed runs score 0 from then on");
        // the crash fired at the first policy action (seed phase ends at
        // 40, eval_every 60): no pre-crash eval point exists
        assert_eq!(out.eval_curve.points.len(), 1);
    }

    #[test]
    fn crash_after_an_eval_keeps_earlier_scores() {
        // crash later than the first eval: the pre-crash point survives
        // and the padding point is appended after it
        let mut cfg = quick_cfg();
        cfg.seed_steps = 70; // first eval (step 60) happens pre-crash
        let venv = VecEnv::new(&cfg, 1).unwrap();
        let mut agent = build_agent(&cfg, venv.obs_len(), venv.act_dim());
        for prm in agent.actor.params_mut() {
            for w in prm.w.iter_mut() {
                *w = f32::NAN;
            }
        }
        let out = train_agent(&cfg, venv, agent);
        assert!(out.crashed);
        assert_eq!(out.final_score, 0.0);
        let repeat = crate::envs::action_repeat(&cfg.task);
        assert_eq!(out.eval_curve.points.len(), 2);
        // eval at step 60 ran the (NaN) policy deterministically -> the
        // evaluator flags the crash and scores it 0
        assert_eq!(out.eval_curve.points[0], ((60 * repeat) as f64, 0.0));
        assert_eq!(out.eval_curve.points[1], (((cfg.steps) * repeat) as f64, 0.0));
    }

    #[test]
    fn storage_knob_reaches_the_agent_and_run_matches_f32_tier() {
        // the knob must thread through build_agent, and under an fp16
        // store an f16 read-only tier is lossless: the whole training
        // run must reproduce the unpacked run's eval curve exactly
        let mut cfg = quick_cfg();
        cfg.preset = "fp16_ours".into();
        let plain = train(&cfg);
        cfg.storage = "f16".into();
        let agent = build_agent(&cfg, 3, 1);
        assert_eq!(agent.half_storage(), Some(crate::lowp::HalfFormat::F16));
        let packed = train(&cfg);
        assert_eq!(plain.eval_curve.points, packed.eval_curve.points);
        assert_eq!(plain.final_score, packed.final_score);
    }

    #[test]
    fn run_many_preserves_order() {
        let mut cfgs = vec![quick_cfg(), quick_cfg()];
        cfgs[1].seed = 9;
        let outs = run_many(&cfgs);
        assert_eq!(outs.len(), 2);
        assert_eq!(outs[0].cfg.seed, 0);
        assert_eq!(outs[1].cfg.seed, 9);
        // same as serial
        let serial = train(&cfgs[1]);
        assert_eq!(outs[1].eval_curve.points, serial.eval_curve.points);
    }

    /// Fresh scratch dir for a checkpoint store; removes any leftover
    /// from a previous (crashed) test process.
    fn ckpt_scratch(tag: &str) -> std::path::PathBuf {
        let d = std::env::temp_dir().join(format!("lprl_trainer_{tag}_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        d
    }

    /// Bit pattern of the policy's deterministic action on a fixed probe
    /// observation — exact equality means the final params match bitwise.
    fn policy_probe(p: &crate::sac::Policy) -> Vec<u32> {
        let obs: Vec<f32> = (0..p.obs_len()).map(|i| ((i as f32) * 0.37).sin()).collect();
        let t = p.obs_tensor(&obs, 1);
        p.act_batch(&t, crate::sac::ActMode::Deterministic)
            .data
            .iter()
            .map(|v| v.to_bits())
            .collect()
    }

    #[test]
    fn strict_kill_and_resume_matches_uninterrupted_run() {
        // the run-forever contract: checkpoint + kill at any injected
        // boundary, then resume from the surviving store, must reproduce
        // the uninterrupted run bitwise — eval curve, replay fingerprint,
        // update counters, and the final policy's action bits
        let base = train(&quick_cfg());
        for (tag, faults) in
            [("round", "kill@80:round"), ("eval", "kill@60:eval"), ("ckpt", "kill@50:ckpt")]
        {
            let dir = ckpt_scratch(&format!("strict_{tag}"));
            let mut kill_cfg = quick_cfg();
            kill_cfg.out_dir = dir.to_string_lossy().into_owned();
            kill_cfg.checkpoint_every = 25;
            kill_cfg.faults = faults.into();
            let killed = train(&kill_cfg);
            assert!(killed.killed, "{faults} must stop the run early");
            assert!(!killed.crashed, "a kill is not a crash");

            let mut res_cfg = quick_cfg();
            res_cfg.resume_from = dir.join("ckpt").to_string_lossy().into_owned();
            let resumed = train(&res_cfg);
            assert!(!resumed.killed && !resumed.crashed);
            assert_eq!(
                resumed.eval_curve.points, base.eval_curve.points,
                "{faults}: resumed eval curve must match the uninterrupted run"
            );
            assert_eq!(
                resumed.replay_fingerprint, base.replay_fingerprint,
                "{faults}: replay contents must match"
            );
            assert_eq!(resumed.updates, base.updates);
            assert_eq!(resumed.skipped_steps, base.skipped_steps);
            assert_eq!(
                policy_probe(resumed.policy.as_ref().unwrap()),
                policy_probe(base.policy.as_ref().unwrap()),
                "{faults}: final params must match bitwise"
            );
            let _ = std::fs::remove_dir_all(&dir);
        }
    }

    #[test]
    fn strict_fp16_resume_restores_scaler_and_skip_state() {
        // the low-precision guardrail state — loss-scaler dynamics, skip
        // counters, and the coerce_nonfinite-adjacent crash flags — must
        // round-trip through a checkpoint: a resumed fp16 run reproduces
        // the uninterrupted run's curve AND its skip accounting
        let mut base_cfg = quick_cfg();
        base_cfg.preset = "fp16_ours".into();
        let base = train(&base_cfg);

        let dir = ckpt_scratch("strict_fp16");
        let mut kill_cfg = base_cfg.clone();
        kill_cfg.out_dir = dir.to_string_lossy().into_owned();
        kill_cfg.checkpoint_every = 25;
        kill_cfg.faults = "kill@80:round".into();
        let killed = train(&kill_cfg);
        assert!(killed.killed && !killed.crashed);

        let mut res_cfg = base_cfg.clone();
        res_cfg.resume_from = dir.join("ckpt").to_string_lossy().into_owned();
        let resumed = train(&res_cfg);
        assert!(!resumed.crashed);
        assert_eq!(resumed.eval_curve.points, base.eval_curve.points);
        assert_eq!(resumed.skipped_steps, base.skipped_steps, "scaler skip state must resume");
        assert_eq!(resumed.updates, base.updates);
        assert_eq!(resumed.replay_fingerprint, base.replay_fingerprint);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn nan_crash_resume_replays_crash_bitwise() {
        // a poisoned-NaN run that crashed right after a checkpoint:
        // resuming must restore the poisoned params bitwise and replay
        // the same crash with the same accounting (no silent "recovery")
        let dir = ckpt_scratch("nan_crash");
        let mut cfg = quick_cfg();
        cfg.out_dir = dir.to_string_lossy().into_owned();
        cfg.checkpoint_every = 20;
        let venv = VecEnv::new(&cfg, 1).unwrap();
        let mut agent = build_agent(&cfg, venv.obs_len(), venv.act_dim());
        for prm in agent.actor.params_mut() {
            for w in prm.w.iter_mut() {
                *w = f32::NAN;
            }
        }
        let first = train_agent(&cfg, venv, agent);
        assert!(first.crashed && !first.killed);
        // the seed phase (40 steps) checkpointed at 20 and 40 before the
        // first policy action crashed the run at step 40
        let store = crate::ckpt::CkptStore::open(dir.join("ckpt"), cfg.ckpt_keep).unwrap();
        let gens: Vec<u64> = store.generations().unwrap().into_iter().map(|(s, _)| s).collect();
        assert_eq!(gens, vec![20, 40], "seed-phase checkpoints written before the crash");

        let mut res_cfg = cfg.clone();
        res_cfg.resume_from = dir.join("ckpt").to_string_lossy().into_owned();
        let venv2 = VecEnv::new(&res_cfg, 1).unwrap();
        let agent2 = build_agent(&res_cfg, venv2.obs_len(), venv2.act_dim());
        // agent2 is healthy: resume must overwrite it with the poisoned
        // checkpointed masters (NaN bits survive the f32 codec) and crash
        let second = train_agent(&res_cfg, venv2, agent2);
        assert!(second.crashed, "resume restores the poisoned params and re-crashes");
        assert_eq!(second.eval_curve.points, first.eval_curve.points);
        assert_eq!(second.final_score, 0.0);
        assert_eq!(second.replay_fingerprint, first.replay_fingerprint);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn pixel_run_smoke() {
        let mut cfg = quick_cfg();
        cfg.pixels = true;
        cfg.image_size = 17;
        cfg.filters = 4;
        cfg.feature_dim = 8;
        cfg.hidden = 16;
        cfg.steps = 50;
        cfg.seed_steps = 30;
        cfg.batch = 4;
        cfg.eval_every = 50;
        let out = train(&cfg);
        assert!(!out.crashed);
    }

    #[test]
    fn vectorized_pixel_run_smoke() {
        let mut cfg = quick_cfg();
        cfg.pixels = true;
        cfg.image_size = 17;
        cfg.filters = 4;
        cfg.feature_dim = 8;
        cfg.hidden = 16;
        cfg.steps = 40;
        cfg.seed_steps = 20;
        cfg.batch = 4;
        cfg.eval_every = 40;
        cfg.num_envs = 3;
        let out = train(&cfg);
        assert!(!out.crashed);
        assert!(!out.eval_curve.points.is_empty());
    }
}
