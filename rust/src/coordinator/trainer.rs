//! The training loop (launcher): seed phase with random actions, then
//! collect-and-update with periodic deterministic evaluation — the same
//! schedule as the reference SAC codebase, plus the paper's crash
//! accounting (a non-finite action scores the run 0 from then on).

use super::pixels::PixelEnvAdapter;
use super::EPISODE_ENV_STEPS;
use crate::config::RunConfig;
use crate::envs::{action_repeat, make_env, sanitize_action, Env};
use crate::replay::{ReplayBuffer, Storage};
use crate::rngs::Pcg64;
use crate::sac::{ActMode, Policy, SacAgent, SacConfig};
use crate::telemetry::{LogHistogram, Series};
use std::sync::atomic::{AtomicUsize, Ordering};

/// Result of one training run.
pub struct TrainOutcome {
    pub cfg: RunConfig,
    /// Evaluation curve: (agent env-steps × action-repeat, mean return).
    pub eval_curve: Series,
    /// Mean return of the final evaluation (0 if crashed).
    pub final_score: f64,
    pub crashed: bool,
    /// |gradient| histogram sampled at a few updates (Figure 6).
    pub grad_hist: LogHistogram,
    pub wall_secs: f64,
    /// Total optimizer steps skipped due to non-finite gradients.
    pub skipped_steps: u64,
    /// Immutable snapshot of the final trained policy — the artifact
    /// the serve layer consumes. Always `Some` from [`train`]; holds a
    /// full copy of the actor (and encoder) weights, so [`run_many`]
    /// (experiment grids that keep every outcome alive and only read
    /// the scalar results) clears it to keep grid memory flat.
    pub policy: Option<Policy>,
}

enum Obs {
    State(Box<dyn Env>),
    Pixels(PixelEnvAdapter),
}

impl Obs {
    fn reset(&mut self, rng: &mut Pcg64) -> Vec<f32> {
        match self {
            Obs::State(e) => e.reset(rng),
            Obs::Pixels(p) => p.reset(rng),
        }
    }
    fn step(&mut self, a: &[f32]) -> (Vec<f32>, f32) {
        match self {
            Obs::State(e) => e.step(a),
            Obs::Pixels(p) => p.step(a),
        }
    }
    fn act_dim(&self) -> usize {
        match self {
            Obs::State(e) => e.act_dim(),
            Obs::Pixels(p) => p.env.act_dim(),
        }
    }
}

fn build_env(cfg: &RunConfig) -> Obs {
    let env = make_env(&cfg.task).unwrap_or_else(|| panic!("unknown task {}", cfg.task));
    if cfg.pixels {
        Obs::Pixels(PixelEnvAdapter::new(env, cfg.image_size, cfg.frame_stack))
    } else {
        Obs::State(env)
    }
}

fn build_agent(cfg: &RunConfig, obs_dim: usize, act_dim: usize) -> SacAgent {
    let (prec, methods) = cfg
        .preset()
        .unwrap_or_else(|| panic!("unknown preset {}", cfg.preset));
    let mut sac_cfg = if cfg.pixels {
        SacConfig::pixels(cfg.feature_dim, act_dim, cfg.hidden)
    } else {
        SacConfig::states(obs_dim, act_dim, cfg.hidden)
    };
    if cfg.lr > 0.0 {
        sac_cfg.lr = cfg.lr;
    }
    if cfg.gamma > 0.0 {
        sac_cfg.gamma = cfg.gamma;
    }
    if cfg.tau > 0.0 {
        sac_cfg.tau = cfg.tau;
    }
    if cfg.init_temp > 0.0 {
        sac_cfg.init_temperature = cfg.init_temp;
    }
    if cfg.min_log_sig != 0.0 {
        sac_cfg.log_sig_lo = cfg.min_log_sig;
    }
    if cfg.pixels {
        SacAgent::new_pixels(
            sac_cfg,
            methods,
            prec,
            cfg.seed,
            cfg.frame_stack * 3,
            cfg.image_size,
            cfg.filters,
        )
    } else {
        SacAgent::new(sac_cfg, methods, prec, cfg.seed)
    }
}

/// Run `episodes` deterministic evaluation episodes one at a time with
/// an immutable [`Policy`] snapshot (batch-1 forwards — the reference
/// path). Returns `None` if the policy produced a non-finite action
/// (the paper's crash condition), otherwise the mean return (sum of raw
/// env rewards over the 1000-env-step episode).
pub fn evaluate_policy(
    policy: &Policy,
    cfg: &RunConfig,
    episodes: usize,
    eval_seed: u64,
) -> Option<f64> {
    let repeat = action_repeat(&cfg.task);
    let steps = EPISODE_ENV_STEPS / repeat;
    let mut totals = vec![0.0f64; episodes];
    for ep in 0..episodes {
        let mut env = build_env(cfg);
        let mut rng = Pcg64::seed_stream(eval_seed, 1000 + ep as u64);
        let mut obs = env.reset(&mut rng);
        for _ in 0..steps {
            let t = policy.obs_tensor(&obs, 1);
            let mut a = policy.act_batch(&t, ActMode::Deterministic).data;
            if !sanitize_action(&mut a) {
                return None; // crash ⇒ the paper scores the run as 0
            }
            for _ in 0..repeat {
                let (o, r) = env.step(&a);
                obs = o;
                totals[ep] += r as f64;
            }
        }
    }
    Some(totals.iter().sum::<f64>() / episodes as f64)
}

/// Same schedule as [`evaluate_policy`], but every episode advances in
/// lockstep with ONE batched forward per agent step (episodes share the
/// GEMMs). Bitwise identical to the looped path: episode RNG streams
/// are untouched, the GEMM backend is batch-size-invariant per row, and
/// per-episode returns are accumulated separately and reduced in the
/// same order. Fixed-length dm_control-style episodes make lockstep
/// exact (no early termination).
pub fn evaluate_policy_batched(
    policy: &Policy,
    cfg: &RunConfig,
    episodes: usize,
    eval_seed: u64,
) -> Option<f64> {
    if episodes == 0 {
        return Some(0.0);
    }
    let repeat = action_repeat(&cfg.task);
    let steps = EPISODE_ENV_STEPS / repeat;
    let obs_len = policy.obs_len();
    let mut envs: Vec<Obs> = (0..episodes).map(|_| build_env(cfg)).collect();
    let mut obs_flat = vec![0.0f32; episodes * obs_len];
    for (ep, env) in envs.iter_mut().enumerate() {
        let mut rng = Pcg64::seed_stream(eval_seed, 1000 + ep as u64);
        let o = env.reset(&mut rng);
        obs_flat[ep * obs_len..(ep + 1) * obs_len].copy_from_slice(&o);
    }
    let mut totals = vec![0.0f64; episodes];
    for _ in 0..steps {
        let t = policy.obs_tensor(&obs_flat, episodes);
        let acts = policy.act_batch(&t, ActMode::Deterministic);
        for (ep, env) in envs.iter_mut().enumerate() {
            let mut a = acts.row(ep).to_vec();
            if !sanitize_action(&mut a) {
                return None;
            }
            for _ in 0..repeat {
                let (o, r) = env.step(&a);
                totals[ep] += r as f64;
                obs_flat[ep * obs_len..(ep + 1) * obs_len].copy_from_slice(&o);
            }
        }
    }
    Some(totals.iter().sum::<f64>() / episodes as f64)
}

/// Trainer-internal eval: snapshot the agent's policy, run the batched
/// evaluator, translate a crash into the agent's crash flag.
fn evaluate(agent: &mut SacAgent, cfg: &RunConfig, episodes: usize, eval_seed: u64) -> f64 {
    let policy = agent.policy();
    match evaluate_policy_batched(&policy, cfg, episodes, eval_seed) {
        Some(score) => score,
        None => {
            agent.crashed = true;
            0.0
        }
    }
}

/// Train one agent per `cfg`; fully deterministic in `cfg.seed`.
pub fn train(cfg: &RunConfig) -> TrainOutcome {
    let t0 = std::time::Instant::now();
    let repeat = action_repeat(&cfg.task);
    let mut env = build_env(cfg);
    let act_dim = env.act_dim();
    let mut rng = Pcg64::seed_stream(cfg.seed, 7);

    let mut obs = env.reset(&mut rng);
    let obs_shape: Vec<usize> = if cfg.pixels {
        vec![cfg.frame_stack * 3, cfg.image_size, cfg.image_size]
    } else {
        vec![obs.len()]
    };
    let mut agent = build_agent(cfg, obs.len(), act_dim);
    let storage = if agent.compute.is_low() { Storage::F16 } else { Storage::F32 };
    let mut replay = ReplayBuffer::new(cfg.replay_capacity, &obs_shape, act_dim, storage);

    let mut eval_curve = Series::new(format!("{}:{}", cfg.task, cfg.preset));
    let mut grad_hist = LogHistogram::new(-12, 4, 2);
    let probe_at: Vec<usize> = (1..=3).map(|i| cfg.steps * i / 4).collect();

    let episode_steps = EPISODE_ENV_STEPS / repeat;
    let mut ep_step = 0usize;
    let mut crashed = false;
    let mut skipped = 0u64;

    for step in 0..cfg.steps {
        // -- act ---------------------------------------------------------
        let mut a = if step < cfg.seed_steps {
            (0..act_dim).map(|_| rng.uniform_in(-1.0, 1.0)).collect::<Vec<f32>>()
        } else {
            match agent.act(&obs, true) {
                Some(a) => a,
                None => {
                    crashed = true;
                    break;
                }
            }
        };
        if !sanitize_action(&mut a) {
            crashed = true;
            break;
        }
        let mut rew = 0.0f32;
        let mut next_obs = obs.clone();
        for _ in 0..repeat {
            let (o, r) = env.step(&a);
            next_obs = o;
            rew += r;
        }
        ep_step += 1;
        let done = ep_step >= episode_steps;
        // dm_control time limits are not true terminals: not_done stays 1
        replay.push(&obs, &a, rew, &next_obs, false);
        obs = next_obs;
        if done {
            obs = env.reset(&mut rng);
            ep_step = 0;
        }

        // -- update ------------------------------------------------------
        if step >= cfg.seed_steps && replay.len() >= cfg.batch {
            if probe_at.contains(&step) {
                agent.grad_probe = Some(Vec::new());
            }
            let batch = if cfg.pixels {
                replay.sample_aug(cfg.batch, 2, &mut rng)
            } else {
                replay.sample(cfg.batch, &mut rng)
            };
            let stats = agent.update(&batch);
            skipped = stats.skipped_steps;
            if let Some(probe) = agent.grad_probe.take() {
                grad_hist.record_all(&probe);
            }
        }

        // -- eval --------------------------------------------------------
        if (step + 1) % cfg.eval_every == 0 || step + 1 == cfg.steps {
            let score = if agent.crashed || crashed {
                0.0
            } else {
                evaluate(&mut agent, cfg, cfg.eval_episodes, cfg.seed ^ 0x5EED)
            };
            eval_curve.push(((step + 1) * repeat) as f64, score);
            if agent.crashed {
                crashed = true;
                break;
            }
        }
    }

    if crashed || agent.crashed {
        // paper: crashed runs are scored as 0 for the rest of training
        eval_curve.push((cfg.steps * repeat) as f64, 0.0);
    }
    let final_score = if crashed || agent.crashed { 0.0 } else { eval_curve.last_y() };
    TrainOutcome {
        cfg: cfg.clone(),
        eval_curve,
        final_score,
        crashed: crashed || agent.crashed,
        grad_hist,
        wall_secs: t0.elapsed().as_secs_f64(),
        skipped_steps: skipped,
        policy: Some(agent.policy()),
    }
}

/// Train many configurations in parallel across OS threads (one run per
/// thread, capped at the host parallelism). Results keep input order.
pub fn run_many(cfgs: &[RunConfig]) -> Vec<TrainOutcome> {
    let n = cfgs.len();
    let mut results: Vec<Option<TrainOutcome>> = (0..n).map(|_| None).collect();
    let next = AtomicUsize::new(0);
    let workers = std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1).min(n.max(1));
    let results_ptr = std::sync::Mutex::new(&mut results);
    std::thread::scope(|s| {
        for _ in 0..workers {
            s.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                let mut out = train(&cfgs[i]);
                // grids only read scalars/curves; don't pin every run's
                // weight snapshot for the lifetime of the whole grid
                out.policy = None;
                results_ptr.lock().unwrap()[i] = Some(out);
            });
        }
    });
    results.into_iter().map(|o| o.expect("worker died")).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quick_cfg() -> RunConfig {
        RunConfig {
            task: "pendulum_swingup".into(),
            preset: "fp32".into(),
            steps: 120,
            seed_steps: 40,
            batch: 16,
            hidden: 24,
            eval_every: 60,
            eval_episodes: 1,
            ..Default::default()
        }
    }

    #[test]
    fn fp32_short_run_completes() {
        let out = train(&quick_cfg());
        assert!(!out.crashed);
        assert!(!out.eval_curve.points.is_empty());
        assert!(out.final_score >= 0.0);
        assert!(out.grad_hist.total() > 0, "grad probe must fire");
    }

    #[test]
    fn fp16_ours_short_run_completes() {
        let mut cfg = quick_cfg();
        cfg.preset = "fp16_ours".into();
        let out = train(&cfg);
        assert!(!out.crashed, "fp16+ours must not crash");
    }

    #[test]
    fn deterministic_given_seed() {
        let cfg = quick_cfg();
        let a = train(&cfg);
        let b = train(&cfg);
        assert_eq!(a.eval_curve.points, b.eval_curve.points);
        let mut cfg2 = cfg.clone();
        cfg2.seed = 1;
        let c = train(&cfg2);
        assert_ne!(a.eval_curve.points, c.eval_curve.points);
    }

    #[test]
    fn run_many_preserves_order() {
        let mut cfgs = vec![quick_cfg(), quick_cfg()];
        cfgs[1].seed = 9;
        let outs = run_many(&cfgs);
        assert_eq!(outs.len(), 2);
        assert_eq!(outs[0].cfg.seed, 0);
        assert_eq!(outs[1].cfg.seed, 9);
        // same as serial
        let serial = train(&cfgs[1]);
        assert_eq!(outs[1].eval_curve.points, serial.eval_curve.points);
    }

    #[test]
    fn pixel_run_smoke() {
        let mut cfg = quick_cfg();
        cfg.pixels = true;
        cfg.image_size = 17;
        cfg.filters = 4;
        cfg.feature_dim = 8;
        cfg.hidden = 16;
        cfg.steps = 50;
        cfg.seed_steps = 30;
        cfg.batch = 4;
        cfg.eval_every = 50;
        let out = train(&cfg);
        assert!(!out.crashed);
    }
}
