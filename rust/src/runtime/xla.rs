//! Offline stand-in for the `xla` crate (PJRT bindings).
//!
//! The build environment has no XLA/PJRT native library and no registry
//! access, so the runtime layer compiles against this API-compatible
//! stub instead of the real `xla` crate. Host-side [`Literal`] plumbing
//! (construction, reshape, readback) is fully functional; anything that
//! would need a real PJRT client ([`PjRtClient::cpu`]) fails with an
//! instructive error, which [`super::Runtime::open`] surfaces to the
//! caller. Every artifact-dependent test and bench already skips cleanly
//! when `artifacts/manifest.txt` is absent, so the native engine — the
//! whole training/experiment stack — is unaffected.
//!
//! Swapping in the real bindings is a one-line change in
//! `runtime/mod.rs` (`pub mod xla;` → `pub use ::xla;`-style re-export)
//! once the dependency is available.

use std::borrow::Borrow;
use std::fmt;
use std::path::Path;

/// Error type mirroring `xla::Error` closely enough for `?` conversion
/// into `anyhow::Error`.
#[derive(Debug, Clone)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable(what: &str) -> Error {
    // tidy-allow(alloc): error path of the offline stub, never hot
    Error(format!(
        "{what}: PJRT is unavailable in this offline build — the `xla` bindings are a stub \
         (rust/src/runtime/xla.rs). The native engine (`lprl train`, examples, experiment \
         harness) is fully functional; executing AOT artifacts requires a build with the \
         real `xla` crate."
    ))
}

/// Element types a [`Literal`] can be read back as.
pub trait NativeType: Copy {
    fn from_f32(x: f32) -> Self;
}

impl NativeType for f32 {
    fn from_f32(x: f32) -> f32 {
        x
    }
}

/// Host tensor: f32 payload plus dimensions (the interface convention —
/// all artifact boundaries are f32).
#[derive(Debug, Clone, PartialEq)]
pub struct Literal {
    data: Vec<f32>,
    dims: Vec<i64>,
}

impl Literal {
    /// 1-D literal from host data.
    pub fn vec1(data: &[f32]) -> Literal {
        // tidy-allow(alloc): literal constructor at the stub FFI boundary
        Literal { data: data.to_vec(), dims: vec![data.len() as i64] }
    }

    /// The literal's dimensions.
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }

    /// Reinterpret the shape (element count must match).
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let n: i64 = dims.iter().product();
        if n as usize != self.data.len() {
            // tidy-allow(alloc): error path of the offline stub
            return Err(Error(format!(
                "reshape: {} elems into shape {dims:?}",
                self.data.len()
            )));
        }
        // tidy-allow(alloc): host-side literal copy at the stub FFI boundary
        Ok(Literal { data: self.data.clone(), dims: dims.to_vec() })
    }

    /// Decompose a tuple literal. The stub cannot produce tuples (they
    /// only come out of executions), so this is unreachable in practice.
    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        Err(unavailable("Literal::to_tuple"))
    }

    /// Read the payload back to host memory.
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        // tidy-allow(alloc): host readback at the stub FFI boundary
        Ok(self.data.iter().map(|&v| T::from_f32(v)).collect())
    }
}

/// Parsed HLO module (the stub only records where it came from).
#[derive(Debug, Clone)]
pub struct HloModuleProto {
    pub source: String,
}

impl HloModuleProto {
    pub fn from_text_file(path: &Path) -> Result<HloModuleProto> {
        // reading the text is host-side work the stub can still do; the
        // failure is deferred to compile/execute
        match std::fs::read_to_string(path) {
            Ok(_) => Ok(HloModuleProto { source: path.display().to_string() }),
            // tidy-allow(alloc): error path of the offline stub
            Err(e) => Err(Error(format!("reading {}: {e}", path.display()))),
        }
    }
}

/// An XLA computation built from a proto.
#[derive(Debug, Clone)]
pub struct XlaComputation {
    pub source: String,
}

impl XlaComputation {
    pub fn from_proto(proto: &HloModuleProto) -> XlaComputation {
        // tidy-allow(alloc): one-time artifact load, offline stub
        XlaComputation { source: proto.source.clone() }
    }
}

/// A compiled executable (never constructible through the stub).
#[derive(Debug)]
pub struct PjRtLoadedExecutable {
    _private: (),
}

/// A device buffer handle (never constructible through the stub).
#[derive(Debug)]
pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(unavailable("PjRtBuffer::to_literal_sync"))
    }
}

impl PjRtLoadedExecutable {
    pub fn execute<L: Borrow<Literal>>(&self, _inputs: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable("PjRtLoadedExecutable::execute"))
    }
}

/// PJRT client handle.
#[derive(Debug)]
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    /// Opening the CPU client is where the stub reports itself.
    pub fn cpu() -> Result<PjRtClient> {
        Err(unavailable("PjRtClient::cpu"))
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(unavailable("PjRtClient::compile"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip_and_reshape() {
        let l = Literal::vec1(&[1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        let r = l.reshape(&[2, 3]).unwrap();
        assert_eq!(r.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        assert!(l.reshape(&[7]).is_err());
    }

    #[test]
    fn client_reports_stub_clearly() {
        let e = PjRtClient::cpu().unwrap_err();
        assert!(e.to_string().contains("offline build"), "{e}");
    }
}
