//! Parser for `artifacts/manifest.txt` — the line-based index emitted by
//! `python/compile/aot.py` (grammar documented there).

use std::collections::BTreeMap;

/// One named tensor at an artifact boundary. All interface tensors are
/// f32 by convention (f16 variants cast internally).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TensorSpec {
    pub name: String,
    pub shape: Vec<usize>,
}

impl TensorSpec {
    pub fn elems(&self) -> usize {
        self.shape.iter().product()
    }
}

/// One lowered HLO artifact.
#[derive(Debug, Clone)]
pub struct ArtifactSpec {
    pub name: String,
    pub file: String,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
}

/// An initial-state blob (raw little-endian f32, concatenated leaves).
#[derive(Debug, Clone)]
pub struct StateSpec {
    pub variant: String,
    pub file: String,
    pub n_leaves: usize,
}

/// Parsed manifest.
#[derive(Debug, Clone, Default)]
pub struct Manifest {
    pub dims: BTreeMap<String, String>,
    pub artifacts: Vec<ArtifactSpec>,
    pub states: Vec<StateSpec>,
}

impl Manifest {
    pub fn parse(text: &str) -> Result<Manifest, String> {
        let mut m = Manifest::default();
        for (lineno, line) in text.lines().enumerate() {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let toks: Vec<&str> = line.split_whitespace().collect();
            let err = |msg: &str| format!("manifest line {}: {msg}: {line}", lineno + 1);
            match toks[0] {
                "dims" => {
                    for t in &toks[1..] {
                        if let Some((k, v)) = t.split_once('=') {
                            m.dims.insert(k.to_string(), v.to_string());
                        }
                    }
                }
                "artifact" => {
                    if toks.len() != 3 {
                        return Err(err("want `artifact <name> <file>`"));
                    }
                    m.artifacts.push(ArtifactSpec {
                        name: toks[1].to_string(),
                        file: toks[2].to_string(),
                        inputs: Vec::new(),
                        outputs: Vec::new(),
                    });
                }
                "in" | "out" => {
                    if toks.len() != 4 || toks[2] != "f32" {
                        return Err(err("want `in|out <name> f32 <dims>`"));
                    }
                    let shape: Result<Vec<usize>, _> =
                        toks[3].split('x').map(|d| d.parse::<usize>()).collect();
                    let spec = TensorSpec {
                        name: toks[1].to_string(),
                        shape: shape.map_err(|_| err("bad shape"))?,
                    };
                    let art = m.artifacts.last_mut().ok_or_else(|| err("no artifact"))?;
                    if toks[0] == "in" {
                        art.inputs.push(spec);
                    } else {
                        art.outputs.push(spec);
                    }
                }
                "state" => {
                    if toks.len() != 4 {
                        return Err(err("want `state <variant> <file> <n>`"));
                    }
                    m.states.push(StateSpec {
                        variant: toks[1].to_string(),
                        file: toks[2].to_string(),
                        n_leaves: toks[3].parse().map_err(|_| err("bad count"))?,
                    });
                }
                _ => return Err(err("unknown directive")),
            }
        }
        Ok(m)
    }

    pub fn artifact(&self, name: &str) -> Option<&ArtifactSpec> {
        self.artifacts.iter().find(|a| a.name == name)
    }

    pub fn state(&self, variant: &str) -> Option<&StateSpec> {
        self.states.iter().find(|s| s.variant == variant)
    }

    /// Integer dim from the `dims` line.
    pub fn dim(&self, key: &str) -> Option<usize> {
        self.dims.get(key)?.parse().ok()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = "\
# comment
dims obs=3 act=1 hidden=64 batch=64 task=pendulum_swingup
artifact train_fp32 train_fp32.hlo.txt
in state.params.actor.l0.b f32 64
in obs f32 64x3
out state.params.actor.l0.b f32 64
out metrics f32 4
state fp32 state_fp32.bin 42
";

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(m.dim("obs"), Some(3));
        assert_eq!(m.dims["task"], "pendulum_swingup");
        let a = m.artifact("train_fp32").unwrap();
        assert_eq!(a.inputs.len(), 2);
        assert_eq!(a.inputs[1].shape, vec![64, 3]);
        assert_eq!(a.inputs[1].elems(), 192);
        assert_eq!(a.outputs.len(), 2);
        let s = m.state("fp32").unwrap();
        assert_eq!(s.n_leaves, 42);
        assert!(m.artifact("nope").is_none());
    }

    #[test]
    fn rejects_malformed() {
        assert!(Manifest::parse("artifact onlyname").is_err());
        assert!(Manifest::parse("in x f32 3x3").is_err(), "tensor before artifact");
        assert!(Manifest::parse("bogus 1 2").is_err());
        assert!(Manifest::parse("artifact a f\nin x f64 3").is_err(), "non-f32");
    }

    #[test]
    fn parses_real_manifest_if_present() {
        // integration check against the actual aot.py output when built
        let path = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts/manifest.txt");
        if let Ok(text) = std::fs::read_to_string(path) {
            let m = Manifest::parse(&text).unwrap();
            for v in ["fp32", "fp16_naive", "fp16_ours"] {
                assert!(m.artifact(&format!("train_{v}")).is_some(), "{v}");
                assert!(m.artifact(&format!("act_{v}")).is_some(), "{v}");
                assert!(m.state(v).is_some(), "{v}");
            }
            let t = m.artifact("train_fp32").unwrap();
            // outputs = state leaves + metrics
            assert_eq!(t.outputs.len(), t.inputs.len() - 7 + 1);
        }
    }
}
