//! PJRT runtime: loads the HLO-text artifacts produced by
//! `python/compile/aot.py` and executes them on the request path.
//! Python is never invoked at runtime — the Rust binary is self-contained
//! once the artifacts have been generated.
//!
//! In the offline build the `xla` PJRT bindings are replaced by the
//! [`xla`] stub module: manifest parsing and literal plumbing work, but
//! opening a PJRT client reports an instructive error. All callers
//! (tests, benches, `lprl serve`) already handle the artifacts-missing /
//! runtime-unavailable path gracefully.

mod manifest;
mod session;
pub mod xla;

pub use manifest::{ArtifactSpec, Manifest, StateSpec, TensorSpec};
pub use session::{Runtime, TrainSession};
