//! PJRT runtime: loads the HLO-text artifacts produced by
//! `python/compile/aot.py` and executes them on the request path.
//! Python is never invoked at runtime — the Rust binary is self-contained
//! once `make artifacts` has run.

mod manifest;
mod session;

pub use manifest::{ArtifactSpec, Manifest, StateSpec, TensorSpec};
pub use session::{Runtime, TrainSession};
