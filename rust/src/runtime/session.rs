//! PJRT session: compile HLO-text artifacts once, keep the training
//! state resident as device buffers, and step entirely in Rust.

use super::manifest::Manifest;
use super::xla;
use anyhow::{anyhow, bail, Context, Result};
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

/// Artifact registry + PJRT client. Compilation is lazy and cached.
/// The executable cache is a `BTreeMap` so iteration order (and any
/// future eviction/debug-dump walk) is name-sorted, not hash-seeded.
pub struct Runtime {
    client: xla::PjRtClient,
    dir: PathBuf,
    pub manifest: Manifest,
    exes: BTreeMap<String, xla::PjRtLoadedExecutable>,
}

impl Runtime {
    /// Open an artifact directory (must contain `manifest.txt`).
    pub fn open(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let text = std::fs::read_to_string(dir.join("manifest.txt")).with_context(|| {
            format!("reading manifest in {dir:?} — generate with `python python/compile/aot.py`")
        })?;
        let manifest = Manifest::parse(&text).map_err(|e| anyhow!(e))?;
        let client = xla::PjRtClient::cpu()?;
        Ok(Runtime { client, dir, manifest, exes: BTreeMap::new() })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Compile (and cache) an artifact by manifest name.
    pub fn compile(&mut self, name: &str) -> Result<()> {
        if self.exes.contains_key(name) {
            return Ok(());
        }
        let art = self
            .manifest
            .artifact(name)
            .ok_or_else(|| anyhow!("unknown artifact {name}"))?;
        let path = self.dir.join(&art.file);
        let proto = xla::HloModuleProto::from_text_file(&path)?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp)?;
        self.exes.insert(name.to_string(), exe);
        Ok(())
    }

    /// Execute an artifact with host literals (owned or borrowed);
    /// returns the decomposed tuple outputs. Validates input count
    /// against the manifest.
    pub fn execute<L: std::borrow::Borrow<xla::Literal>>(
        &mut self,
        name: &str,
        inputs: &[L],
    ) -> Result<Vec<xla::Literal>> {
        self.compile(name)?;
        let art =
            self.manifest.artifact(name).ok_or_else(|| anyhow!("unknown artifact {name}"))?;
        if inputs.len() != art.inputs.len() {
            bail!(
                "{name}: got {} inputs, manifest wants {}",
                inputs.len(),
                art.inputs.len()
            );
        }
        let exe =
            self.exes.get(name).ok_or_else(|| anyhow!("artifact {name} failed to compile"))?;
        let result = exe.execute::<L>(inputs)?;
        let lit = result[0][0].to_literal_sync()?;
        let outs = lit.to_tuple()?;
        if outs.len() != art.outputs.len() {
            bail!("{name}: got {} outputs, manifest wants {}", outs.len(), art.outputs.len());
        }
        Ok(outs)
    }

    /// Build a literal of the given shape from f32 data.
    pub fn literal(data: &[f32], shape: &[usize]) -> Result<xla::Literal> {
        // tidy-allow(alloc): shape conversion at the runtime FFI boundary;
        // not on the in-process learner loop
        let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
        Ok(xla::Literal::vec1(data).reshape(&dims)?)
    }

    /// Load an initial-state blob into per-leaf literals for the
    /// `train_<variant>` artifact.
    pub fn load_state(&self, variant: &str) -> Result<Vec<xla::Literal>> {
        let spec = self
            .manifest
            .state(variant)
            .ok_or_else(|| anyhow!("no state for {variant}"))?;
        let art = self
            .manifest
            .artifact(&format!("train_{variant}"))
            .ok_or_else(|| anyhow!("no train artifact for {variant}"))?;
        let path = self.dir.join(&spec.file);
        let bytes = std::fs::read(&path)
            .with_context(|| format!("reading train artifact {}", path.display()))?;
        let mut floats = vec![0f32; bytes.len() / 4];
        for (i, ch) in bytes.chunks_exact(4).enumerate() {
            floats[i] = f32::from_le_bytes([ch[0], ch[1], ch[2], ch[3]]);
        }
        let mut out = Vec::with_capacity(spec.n_leaves);
        let mut off = 0;
        for t in art.inputs.iter().take(spec.n_leaves) {
            let n = t.elems();
            if off + n > floats.len() {
                bail!("state blob too short at {}", t.name);
            }
            out.push(Self::literal(&floats[off..off + n], &t.shape)?);
            off += n;
        }
        if off != floats.len() {
            bail!("state blob has {} trailing floats", floats.len() - off);
        }
        Ok(out)
    }
}

/// A full training session over the `train_<variant>` artifact: owns the
/// state leaves and feeds batches. This is the L3 hot path of the
/// three-layer architecture — no Python anywhere.
pub struct TrainSession {
    pub runtime: Runtime,
    pub variant: String,
    /// Current state leaves (kept as host literals between steps; PJRT
    /// CPU shares the host memory so copies are cheap — see §Perf).
    pub state: Vec<xla::Literal>,
    n_state: usize,
    pub steps: u64,
}

impl TrainSession {
    pub fn new(artifact_dir: impl AsRef<Path>, variant: &str) -> Result<Self> {
        let mut runtime = Runtime::open(artifact_dir)?;
        runtime.compile(&format!("train_{variant}"))?;
        runtime.compile(&format!("act_{variant}"))?;
        let state = runtime.load_state(variant)?;
        let n_state = state.len();
        Ok(TrainSession { runtime, variant: variant.to_string(), state, n_state, steps: 0 })
    }

    pub fn dims(&self) -> (usize, usize, usize) {
        let m = &self.runtime.manifest;
        (
            m.dim("obs").unwrap_or(0),
            m.dim("act").unwrap_or(0),
            m.dim("batch").unwrap_or(0),
        )
    }

    /// One fused train step. `batch` = (obs, act, rew, next_obs,
    /// not_done, eps_next, eps_cur) as flat f32 slices. Returns the 4
    /// metrics [critic_loss, q_mean, logp_mean, alpha].
    #[allow(clippy::too_many_arguments)]
    pub fn step(
        &mut self,
        obs: &[f32],
        act: &[f32],
        rew: &[f32],
        next_obs: &[f32],
        not_done: &[f32],
        eps_next: &[f32],
        eps_cur: &[f32],
    ) -> Result<[f32; 4]> {
        let name = format!("train_{}", self.variant);
        let art = self
            .runtime
            .manifest
            .artifact(&name)
            .ok_or_else(|| anyhow!("unknown artifact {name}"))?
            .clone();
        let batch_specs = &art.inputs[self.n_state..];
        let mut batch_lits: Vec<xla::Literal> = Vec::with_capacity(7);
        for (spec, data) in batch_specs
            .iter()
            .zip([obs, act, rew, next_obs, not_done, eps_next, eps_cur])
        {
            if spec.elems() != data.len() {
                bail!("{}: want {} elems got {}", spec.name, spec.elems(), data.len());
            }
            batch_lits.push(Runtime::literal(data, &spec.shape)?);
        }
        // borrow state leaves + batch literals without copying state
        let inputs: Vec<&xla::Literal> =
            self.state.iter().chain(batch_lits.iter()).collect();
        let mut outs = self.runtime.execute(&name, &inputs)?;
        let metrics_lit = outs.pop().ok_or_else(|| anyhow!("no metrics"))?;
        let metrics = metrics_lit.to_vec::<f32>()?;
        self.state = outs;
        self.steps += 1;
        Ok([metrics[0], metrics[1], metrics[2], metrics[3]])
    }

    /// Policy inference: single observation -> action (length = act dim).
    pub fn act(&mut self, obs: &[f32], eps: &[f32]) -> Result<Vec<f32>> {
        let name = format!("act_{}", self.variant); // tidy-allow(alloc): runtime FFI boundary, not the in-process learner loop
        let art = self
            .runtime
            .manifest
            .artifact(&name)
            .ok_or_else(|| anyhow!("unknown artifact {name}"))?
            .clone(); // tidy-allow(alloc): manifest metadata at the runtime FFI boundary
        let n_actor = art.inputs.len() - 2;
        // actor leaves are a prefix of the state (params.actor.* come
        // first in sorted-key order)
        // tidy-allow(alloc): literal staging at the runtime FFI boundary
        let mut inputs: Vec<xla::Literal> = Vec::with_capacity(art.inputs.len());
        let train = self
            .runtime
            .manifest
            .artifact(&format!("train_{}", self.variant)) // tidy-allow(alloc): runtime FFI boundary
            .ok_or_else(|| anyhow!("no train artifact for {}", self.variant))?
            .clone(); // tidy-allow(alloc): manifest metadata at the runtime FFI boundary
        for spec in art.inputs.iter().take(n_actor) {
            // find the matching state leaf by suffix name
            let want = spec.name.strip_prefix("actor.").unwrap_or(&spec.name);
            let idx = train
                .inputs
                .iter()
                .position(|t| t.name == format!("state.params.actor.{want}")) // tidy-allow(alloc): runtime FFI boundary
                .ok_or_else(|| anyhow!("actor leaf {want} not in state"))?;
            inputs.push(self.state[idx].clone()); // tidy-allow(alloc): literal handle for the runtime call
        }
        inputs.push(Runtime::literal(obs, art.inputs[n_actor].shape.as_slice())?);
        inputs.push(Runtime::literal(eps, art.inputs[n_actor + 1].shape.as_slice())?);
        let outs = self.runtime.execute(&name, &inputs)?;
        Ok(outs[0].to_vec::<f32>()?)
    }

    /// Copy a named state leaf back to host f32 (telemetry/inspection).
    pub fn state_leaf(&self, name: &str) -> Result<Vec<f32>> {
        let train = self
            .runtime
            .manifest
            .artifact(&format!("train_{}", self.variant))
            .ok_or_else(|| anyhow!("no train artifact for {}", self.variant))?;
        let idx = train
            .inputs
            .iter()
            .position(|t| t.name == name)
            .ok_or_else(|| anyhow!("no leaf {name}"))?;
        Ok(self.state[idx].to_vec::<f32>()?)
    }
}
