//! Collection-throughput benchmark: env-steps/sec of the collector as a
//! function of `num_envs`, across precision presets and **interleave
//! modes** (strict single-thread loop vs the async collector/learner
//! pipeline with pooled env stepping). The paper's Table 3 speedups come
//! from amortizing half-precision compute over batches; this bench
//! tracks how far one shared forward per collect round (strict) and
//! overlapping collection with learning (async) amortize the rollout
//! the same way.
//!
//! Writes two JSON reports at the repo root:
//! * `BENCH_collect.json` — the strict-mode states matrix (schema
//!   unchanged from PR 3);
//! * `BENCH_async.json` — the sync-vs-async matrix: env-steps/sec,
//!   updates/sec and snapshot-refresh latency per (preset, mode,
//!   num_envs), for states *and* a pixel preset (where pooled parallel
//!   rendering is the payoff).
//!
//! ```bash
//! cargo bench --bench collect_throughput            # full run, writes JSON
//! cargo bench --bench collect_throughput -- --test  # CI smoke: tiny, no JSON
//! ```
//!
//! Before timing anything the bench asserts the correctness gates:
//! identical `num_envs = 4` runs must match bitwise in *both* modes.

use lprl::config::RunConfig;
use lprl::coordinator::train;
use std::fmt::Write as _;

struct Row {
    preset: &'static str,
    mode: &'static str,
    pixels: bool,
    num_envs: usize,
    collect_sps: f64,
    updates_per_sec: f64,
    snapshot_refresh_us: f64,
    wall_secs: f64,
    final_score: f64,
}

struct Shape {
    steps: usize,
    hidden: usize,
    batch: usize,
    pixel_steps: usize,
    image_size: usize,
    filters: usize,
    feature_dim: usize,
}

fn bench_cfg(preset: &str, mode: &'static str, pixels: bool, num_envs: usize, sh: &Shape) -> RunConfig {
    let steps = if pixels { sh.pixel_steps } else { sh.steps };
    let mut cfg = RunConfig {
        task: "pendulum_swingup".into(),
        preset: preset.into(),
        steps,
        seed_steps: (steps / 8).max(num_envs),
        batch: if pixels { sh.batch.min(16) } else { sh.batch },
        hidden: if pixels { sh.hidden.min(64) } else { sh.hidden },
        eval_every: steps, // single final eval, outside both stage timers
        eval_episodes: 1,
        num_envs,
        sync_mode: mode.into(),
        ..Default::default()
    };
    if pixels {
        cfg.pixels = true;
        cfg.image_size = sh.image_size;
        cfg.filters = sh.filters;
        cfg.feature_dim = sh.feature_dim;
    }
    cfg
}

fn bench_one(preset: &'static str, mode: &'static str, pixels: bool, num_envs: usize, sh: &Shape) -> Row {
    let cfg = bench_cfg(preset, mode, pixels, num_envs, sh);
    let out = train(&cfg);
    assert!(!out.crashed, "{preset} {mode} pixels={pixels} num_envs={num_envs} crashed");
    Row {
        preset,
        mode,
        pixels,
        num_envs,
        collect_sps: out.collect_steps_per_sec,
        updates_per_sec: out.updates_per_sec,
        snapshot_refresh_us: if out.snapshot_refreshes > 0 {
            out.snapshot_publish_secs * 1e6 / out.snapshot_refreshes as f64
        } else {
            0.0
        },
        wall_secs: out.wall_secs,
        final_score: out.final_score,
    }
}

struct SimdF32Row {
    preset: &'static str,
    /// Dispatch level the leg ran at: "scalar" (forced) or the detected tier.
    simd: String,
    num_envs: usize,
    collect_sps: f64,
    updates_per_sec: f64,
}

/// Spawn `lprl train` in a child process so the scalar leg can force
/// `LPRL_SIMD=0` — the GEMM dispatch level is detected once per process,
/// so an in-process scalar row is impossible once any kernel has run.
/// Parses the trainer's `throughput:` summary line.
fn collect_via_cli(preset: &'static str, num_envs: usize, sh: &Shape, force_scalar: bool) -> SimdF32Row {
    let exe = env!("CARGO_BIN_EXE_lprl");
    let out_dir = std::env::temp_dir().join(format!(
        "lprl-collect-simd-{}-{preset}-{}",
        std::process::id(),
        if force_scalar { "scalar" } else { "auto" }
    ));
    let mut cmd = std::process::Command::new(exe);
    cmd.arg("train");
    cmd.arg("task=pendulum_swingup");
    cmd.arg(format!("preset={preset}"));
    cmd.arg(format!("steps={}", sh.steps));
    cmd.arg(format!("seed_steps={}", (sh.steps / 8).max(num_envs)));
    cmd.arg(format!("batch={}", sh.batch));
    cmd.arg(format!("hidden={}", sh.hidden));
    cmd.arg(format!("eval_every={}", sh.steps));
    cmd.arg("eval_episodes=1");
    cmd.arg(format!("num_envs={num_envs}"));
    cmd.arg(format!("out_dir={}", out_dir.display()));
    if force_scalar {
        cmd.env("LPRL_SIMD", "0");
    } else {
        cmd.env_remove("LPRL_SIMD");
    }
    let out = cmd.output().expect("failed to launch lprl train");
    assert!(
        out.status.success(),
        "lprl train {preset} (force_scalar={force_scalar}) failed:\n{}",
        String::from_utf8_lossy(&out.stderr)
    );
    let stdout = String::from_utf8_lossy(&out.stdout);
    let line = stdout
        .lines()
        .find(|l| l.starts_with("throughput:"))
        .expect("trainer printed no throughput line");
    let toks: Vec<&str> = line.split_whitespace().collect();
    let grab = |key: &str| -> f64 {
        let i = toks.iter().position(|t| *t == key).unwrap();
        toks[i + 1].parse().unwrap()
    };
    let collect_sps = grab("collect");
    let updates_per_sec = grab("learner");
    let _ = std::fs::remove_dir_all(&out_dir);
    SimdF32Row {
        preset,
        simd: if force_scalar {
            "scalar".into()
        } else {
            lprl::nn::simd::detect().name().into()
        },
        num_envs,
        collect_sps,
        updates_per_sec,
    }
}

/// The PR-3 report: strict-mode states rows only, schema unchanged.
fn write_collect_json(
    task: &str,
    steps: usize,
    hidden: usize,
    rows: &[Row],
    simd_rows: &[SimdF32Row],
) -> std::io::Result<std::path::PathBuf> {
    let rows: Vec<&Row> = rows.iter().filter(|r| r.mode == "strict" && !r.pixels).collect();
    let mut out = String::new();
    out.push_str("{\n  \"bench\": \"collect\",\n");
    let _ = writeln!(out, "  \"task\": \"{task}\",");
    let _ = writeln!(out, "  \"steps\": {steps},");
    let _ = writeln!(out, "  \"hidden\": {hidden},");
    out.push_str("  \"rows\": [\n");
    for (i, r) in rows.iter().enumerate() {
        let _ = write!(
            out,
            "    {{\"preset\": \"{}\", \"num_envs\": {}, \"collect_steps_per_sec\": {:.1}, \"updates_per_sec\": {:.2}, \"wall_secs\": {:.3}, \"final_score\": {:.2}}}",
            r.preset, r.num_envs, r.collect_sps, r.updates_per_sec, r.wall_secs, r.final_score
        );
        out.push_str(if i + 1 < rows.len() { ",\n" } else { "\n" });
    }
    out.push_str("  ],\n  \"scaling\": [\n");
    let presets: Vec<&str> = {
        let mut p: Vec<&str> = rows.iter().map(|r| r.preset).collect();
        p.dedup();
        p
    };
    for (i, preset) in presets.iter().enumerate() {
        let base = rows
            .iter()
            .find(|r| r.preset == *preset && r.num_envs == 1)
            .expect("num_envs=1 row");
        let top = rows
            .iter()
            .filter(|r| r.preset == *preset)
            .max_by_key(|r| r.num_envs)
            .unwrap();
        let _ = write!(
            out,
            "    {{\"preset\": \"{}\", \"num_envs\": {}, \"collect_speedup_vs_1\": {:.3}}}",
            preset,
            top.num_envs,
            top.collect_sps / base.collect_sps
        );
        out.push_str(if i + 1 < presets.len() { ",\n" } else { "\n" });
    }
    out.push_str("  ],\n  \"simd_f32\": [\n");
    for (i, r) in simd_rows.iter().enumerate() {
        let _ = write!(
            out,
            "    {{\"preset\": \"{}\", \"simd\": \"{}\", \"num_envs\": {}, \"collect_steps_per_sec\": {:.1}, \"updates_per_sec\": {:.2}}}",
            r.preset, r.simd, r.num_envs, r.collect_sps, r.updates_per_sec
        );
        out.push_str(if i + 1 < simd_rows.len() { ",\n" } else { "\n" });
    }
    out.push_str("  ]\n}\n");
    write_report("BENCH_collect.json", &out)
}

/// The sync-vs-async matrix: every row, plus async-vs-strict speedup
/// summaries at the largest env count per (preset, pixels) pair.
fn write_async_json(task: &str, sh: &Shape, rows: &[Row]) -> std::io::Result<std::path::PathBuf> {
    let mut out = String::new();
    out.push_str("{\n  \"bench\": \"collect_async\",\n");
    let _ = writeln!(out, "  \"task\": \"{task}\",");
    let _ = writeln!(out, "  \"states_steps\": {}, \"pixel_steps\": {},", sh.steps, sh.pixel_steps);
    let _ = writeln!(out, "  \"image_size\": {},", sh.image_size);
    out.push_str("  \"rows\": [\n");
    for (i, r) in rows.iter().enumerate() {
        let _ = write!(
            out,
            "    {{\"preset\": \"{}\", \"mode\": \"{}\", \"pixels\": {}, \"num_envs\": {}, \"collect_steps_per_sec\": {:.1}, \"updates_per_sec\": {:.2}, \"snapshot_refresh_us\": {:.1}, \"wall_secs\": {:.3}}}",
            r.preset, r.mode, r.pixels, r.num_envs, r.collect_sps, r.updates_per_sec, r.snapshot_refresh_us, r.wall_secs
        );
        out.push_str(if i + 1 < rows.len() { ",\n" } else { "\n" });
    }
    out.push_str("  ],\n  \"async_vs_strict\": [\n");
    let mut pairs: Vec<(&str, bool)> = rows.iter().map(|r| (r.preset, r.pixels)).collect();
    pairs.dedup();
    let mut summaries = Vec::new();
    for (preset, pixels) in pairs {
        let sel = |mode: &str| {
            rows.iter()
                .filter(|r| r.preset == preset && r.pixels == pixels && r.mode == mode)
                .max_by_key(|r| r.num_envs)
        };
        if let (Some(st), Some(asy)) = (sel("strict"), sel("async")) {
            if st.num_envs == asy.num_envs {
                summaries.push(format!(
                    "    {{\"preset\": \"{}\", \"pixels\": {}, \"num_envs\": {}, \"collect_speedup_async\": {:.3}, \"wall_speedup_async\": {:.3}}}",
                    preset,
                    pixels,
                    st.num_envs,
                    asy.collect_sps / st.collect_sps,
                    st.wall_secs / asy.wall_secs
                ));
            }
        }
    }
    out.push_str(&summaries.join(",\n"));
    out.push_str("\n  ]\n}\n");
    write_report("BENCH_async.json", &out)
}

fn write_report(name: &str, contents: &str) -> std::io::Result<std::path::PathBuf> {
    let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .unwrap()
        .join(name);
    std::fs::write(&path, contents)?;
    Ok(path)
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--test");
    let (shape, envs, pixel_envs, presets): (Shape, Vec<usize>, Vec<usize>, Vec<&'static str>) =
        if smoke {
            (
                Shape { steps: 64, hidden: 32, batch: 16, pixel_steps: 32, image_size: 17, filters: 4, feature_dim: 8 },
                vec![1, 4],
                vec![4],
                vec!["fp16_ours"],
            )
        } else {
            (
                Shape { steps: 1500, hidden: 256, batch: 128, pixel_steps: 256, image_size: 21, filters: 8, feature_dim: 16 },
                vec![1, 2, 4, 8],
                vec![4, 8],
                vec!["fp32", "fp16_ours"],
            )
        };
    let modes: [&'static str; 2] = ["strict", "async"];

    // -- correctness gates: both interleaves deterministic in the seed --
    for mode in modes {
        let det_cfg = bench_cfg("fp16_ours", mode, false, 4, &Shape {
            steps: 48,
            hidden: 24,
            batch: 8,
            pixel_steps: 32,
            image_size: 17,
            filters: 4,
            feature_dim: 8,
        });
        let a = train(&det_cfg);
        let b = train(&det_cfg);
        assert_eq!(
            a.eval_curve.points, b.eval_curve.points,
            "{mode} num_envs=4 training must be deterministic in the seed"
        );
        assert_eq!(a.replay_fingerprint, b.replay_fingerprint, "{mode} transition multiset");
        println!("determinism gate [{mode}]: two num_envs=4 runs match  OK");
    }

    let mut rows = Vec::new();
    for &preset in &presets {
        for (pixels, env_list) in [(false, &envs), (true, &pixel_envs)] {
            if pixels && preset == "fp32" {
                continue; // pixel matrix: the paper's fp16_ours operating point
            }
            for mode in modes {
                for &n in env_list {
                    let row = bench_one(preset, mode, pixels, n, &shape);
                    println!(
                        "{:>9} {:>6} pixels={:<5} num_envs {:>2}: collect {:>9.1} steps/s  learner {:>7.2} upd/s  snap {:>6.1} us  wall {:>6.2}s",
                        row.preset, row.mode, row.pixels, row.num_envs,
                        row.collect_sps, row.updates_per_sec, row.snapshot_refresh_us, row.wall_secs
                    );
                    rows.push(row);
                }
            }
        }
    }
    for (pixels, label) in [(false, "states"), (true, "pixels")] {
        for &preset in &presets {
            let top = |mode: &str| {
                rows.iter()
                    .filter(|r| r.preset == preset && r.pixels == pixels && r.mode == mode)
                    .max_by_key(|r| r.num_envs)
            };
            if let (Some(st), Some(asy)) = (top("strict"), top("async")) {
                println!(
                    "{preset:>9} {label}: async vs strict @ num_envs {}: collect {:.2}x  wall {:.2}x",
                    st.num_envs,
                    asy.collect_sps / st.collect_sps,
                    st.wall_secs / asy.wall_secs
                );
            }
        }
    }

    // -- simd_f32: the same collector, auto dispatch vs LPRL_SIMD=0 -------
    let sf_envs = *envs.last().unwrap();
    let sf_presets: &[&'static str] = if smoke { &["fp16_ours"] } else { &["fp32", "fp16_ours"] };
    let mut simd_rows = Vec::new();
    for &preset in sf_presets {
        let auto = collect_via_cli(preset, sf_envs, &shape, false);
        let scalar = collect_via_cli(preset, sf_envs, &shape, true);
        println!(
            "simd_f32 collect {:>10} num_envs {:>2}: {} {:>9.1} steps/s  vs scalar {:>9.1} steps/s  ({:.2}x)",
            preset,
            sf_envs,
            auto.simd,
            auto.collect_sps,
            scalar.collect_sps,
            auto.collect_sps / scalar.collect_sps
        );
        simd_rows.push(auto);
        simd_rows.push(scalar);
    }

    if smoke {
        println!("smoke mode: no JSON written");
        return;
    }
    match write_collect_json("pendulum_swingup", shape.steps, shape.hidden, &rows, &simd_rows) {
        Ok(p) => println!("wrote {}", p.display()),
        Err(e) => eprintln!("could not write BENCH_collect.json: {e}"),
    }
    match write_async_json("pendulum_swingup", &shape, &rows) {
        Ok(p) => println!("wrote {}", p.display()),
        Err(e) => eprintln!("could not write BENCH_async.json: {e}"),
    }
}
