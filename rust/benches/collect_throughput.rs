//! Collection-throughput benchmark: env-steps/sec of the vectorized
//! collector as a function of `num_envs`, across precision presets, on
//! the states task. The paper's Table 3 speedups come from amortizing
//! half-precision compute over batches; this bench tracks how far one
//! shared forward per collect round amortizes the rollout the same way.
//! Writes `BENCH_collect.json` at the repo root next to
//! `BENCH_gemm.json` and `BENCH_serve.json`.
//!
//! ```bash
//! cargo bench --bench collect_throughput            # full run, writes JSON
//! cargo bench --bench collect_throughput -- --test  # CI smoke: tiny, no JSON
//! ```
//!
//! Before timing anything the bench asserts the vectorized-collection
//! correctness invariant: two identical `num_envs = 4` runs produce the
//! same eval curve (determinism in the seed).

use lprl::config::RunConfig;
use lprl::coordinator::train;
use std::fmt::Write as _;

struct Row {
    preset: &'static str,
    num_envs: usize,
    collect_sps: f64,
    updates_per_sec: f64,
    wall_secs: f64,
    final_score: f64,
}

fn bench_cfg(preset: &str, num_envs: usize, steps: usize, hidden: usize, batch: usize) -> RunConfig {
    RunConfig {
        task: "pendulum_swingup".into(),
        preset: preset.into(),
        steps,
        seed_steps: (steps / 8).max(num_envs),
        batch,
        hidden,
        eval_every: steps, // single final eval, outside both stage timers
        eval_episodes: 1,
        num_envs,
        ..Default::default()
    }
}

fn bench_one(preset: &'static str, num_envs: usize, steps: usize, hidden: usize, batch: usize) -> Row {
    let cfg = bench_cfg(preset, num_envs, steps, hidden, batch);
    let out = train(&cfg);
    assert!(!out.crashed, "{preset} num_envs={num_envs} crashed");
    Row {
        preset,
        num_envs,
        collect_sps: out.collect_steps_per_sec,
        updates_per_sec: out.updates_per_sec,
        wall_secs: out.wall_secs,
        final_score: out.final_score,
    }
}

fn write_json(task: &str, steps: usize, hidden: usize, rows: &[Row]) -> std::io::Result<std::path::PathBuf> {
    let mut out = String::new();
    out.push_str("{\n  \"bench\": \"collect\",\n");
    let _ = writeln!(out, "  \"task\": \"{task}\",");
    let _ = writeln!(out, "  \"steps\": {steps},");
    let _ = writeln!(out, "  \"hidden\": {hidden},");
    out.push_str("  \"rows\": [\n");
    for (i, r) in rows.iter().enumerate() {
        let _ = write!(
            out,
            "    {{\"preset\": \"{}\", \"num_envs\": {}, \"collect_steps_per_sec\": {:.1}, \"updates_per_sec\": {:.2}, \"wall_secs\": {:.3}, \"final_score\": {:.2}}}",
            r.preset, r.num_envs, r.collect_sps, r.updates_per_sec, r.wall_secs, r.final_score
        );
        out.push_str(if i + 1 < rows.len() { ",\n" } else { "\n" });
    }
    out.push_str("  ],\n  \"scaling\": [\n");
    let presets: Vec<&str> = {
        let mut p: Vec<&str> = rows.iter().map(|r| r.preset).collect();
        p.dedup();
        p
    };
    for (i, preset) in presets.iter().enumerate() {
        let of = |n: usize| rows.iter().find(|r| r.preset == *preset && r.num_envs == n);
        let base = of(1).expect("num_envs=1 row");
        let top = rows
            .iter()
            .filter(|r| r.preset == *preset)
            .max_by_key(|r| r.num_envs)
            .unwrap();
        let _ = write!(
            out,
            "    {{\"preset\": \"{}\", \"num_envs\": {}, \"collect_speedup_vs_1\": {:.3}}}",
            preset,
            top.num_envs,
            top.collect_sps / base.collect_sps
        );
        out.push_str(if i + 1 < presets.len() { ",\n" } else { "\n" });
    }
    out.push_str("  ]\n}\n");
    let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .unwrap()
        .join("BENCH_collect.json");
    std::fs::write(&path, out)?;
    Ok(path)
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--test");
    let (steps, hidden, batch, envs, presets): (usize, usize, usize, Vec<usize>, Vec<&'static str>) =
        if smoke {
            (64, 32, 16, vec![1, 4], vec!["fp16_ours"])
        } else {
            (1500, 256, 128, vec![1, 2, 4, 8], vec!["fp32", "fp16_ours"])
        };

    // -- correctness gate: vectorized collection is deterministic ------
    let det_cfg = bench_cfg("fp16_ours", 4, 48, 24, 8);
    let a = train(&det_cfg);
    let b = train(&det_cfg);
    assert_eq!(
        a.eval_curve.points, b.eval_curve.points,
        "num_envs=4 training must be deterministic in the seed"
    );
    println!("determinism gate: two num_envs=4 runs match  OK");

    let mut rows = Vec::new();
    for &preset in &presets {
        for &n in &envs {
            let row = bench_one(preset, n, steps, hidden, batch);
            println!(
                "{:>9}  num_envs {:>2}: collect {:>9.1} steps/s  learner {:>7.2} upd/s  wall {:>6.2}s",
                row.preset, row.num_envs, row.collect_sps, row.updates_per_sec, row.wall_secs
            );
            rows.push(row);
        }
        let base = rows.iter().find(|r| r.preset == preset && r.num_envs == 1).unwrap();
        let top = rows.iter().filter(|r| r.preset == preset).max_by_key(|r| r.num_envs).unwrap();
        println!(
            "{:>9}  collect speedup (num_envs {} vs 1): {:.2}x",
            preset,
            top.num_envs,
            top.collect_sps / base.collect_sps
        );
    }

    if smoke {
        println!("smoke mode: no JSON written");
        return;
    }
    match write_json("pendulum_swingup", steps, hidden, &rows) {
        Ok(p) => println!("wrote {}", p.display()),
        Err(e) => eprintln!("could not write BENCH_collect.json: {e}"),
    }
}
