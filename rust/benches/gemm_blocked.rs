//! Bench: the blocked GEMM backend vs the seed's row-parallel scalar
//! GEMMs (`nn::gemm::reference`), at SAC-sized shapes. Writes the
//! results to `BENCH_gemm.json` at the repository root so the perf
//! trajectory is tracked from PR 1 onward.
//!
//! ```bash
//! cargo bench --bench gemm_blocked            # full run, writes JSON
//! cargo bench --bench gemm_blocked -- --test  # CI smoke: tiny shapes
//! ```

use lprl::lowp::{HalfFormat, Precision};
use lprl::nn::gemm::{self, reference};
use lprl::nn::simd;
use lprl::rngs::Pcg64;
use std::fmt::Write as _;
use std::time::Instant;

type GemmFn = fn(&[f32], &[f32], &mut [f32], usize, usize, usize);

struct Row {
    op: &'static str,
    m: usize,
    k: usize,
    n: usize,
    blocked_ms: f64,
    reference_ms: f64,
}

impl Row {
    fn speedup(&self) -> f64 {
        self.reference_ms / self.blocked_ms
    }

    fn gflops(&self) -> f64 {
        2.0 * (self.m * self.k * self.n) as f64 / (self.blocked_ms * 1e6)
    }
}

/// One packed-half GEMM measurement: the u16-storage kernel pinned to a
/// SIMD level, against the blocked f32 kernel at the same shape.
struct HalfRow {
    fmt: &'static str,
    level: &'static str,
    m: usize,
    k: usize,
    n: usize,
    half_ms: f64,
    f32_ms: f64,
    scalar_ms: f64,
}

impl HalfRow {
    /// Throughput vs the f32 B-operand path (the bandwidth win).
    fn speedup_vs_f32(&self) -> f64 {
        self.f32_ms / self.half_ms
    }

    /// Throughput vs the scalar widening oracle (the SIMD win).
    fn speedup_vs_scalar(&self) -> f64 {
        self.scalar_ms / self.half_ms
    }

    /// Packed B-panel stream rate in GB/s (2 bytes per weight).
    fn b_gbs(&self) -> f64 {
        2.0 * (self.k * self.n) as f64 / (self.half_ms * 1e6)
    }
}

/// One f32 SIMD-plane measurement: a fused bias+quantize GEMM kernel
/// (or the slice RNE quantizer) pinned to a level, vs the scalar oracle
/// at the same shape. The bench asserts bitwise parity between the two
/// levels before timing anything.
struct SimdF32Row {
    op: &'static str,
    level: &'static str,
    m: usize,
    k: usize,
    n: usize,
    ms: f64,
    scalar_ms: f64,
    /// Bytes streamed per call (A+B read, C written; slice in+out for
    /// the quantizer).
    bytes: usize,
}

impl SimdF32Row {
    fn speedup_vs_scalar(&self) -> f64 {
        self.scalar_ms / self.ms
    }

    fn gbs(&self) -> f64 {
        self.bytes as f64 / (self.ms * 1e6)
    }
}

/// Median wall time of `f` over `iters` runs, in ms.
fn median_ms(iters: usize, mut f: impl FnMut()) -> f64 {
    f(); // warmup (also faults in the buffers)
    let mut times: Vec<f64> = (0..iters)
        .map(|_| {
            let t0 = Instant::now();
            f();
            t0.elapsed().as_secs_f64() * 1000.0
        })
        .collect();
    times.sort_by(|x, y| x.partial_cmp(y).unwrap());
    times[times.len() / 2]
}

/// Median-of-iters wall time for one gemm call, in ms.
#[allow(clippy::too_many_arguments)]
fn time_ms(f: GemmFn, a: &[f32], b: &[f32], c: &mut [f32], m: usize, k: usize, n: usize, iters: usize) -> f64 {
    // warmup (also faults in the buffers)
    c.iter_mut().for_each(|v| *v = 0.0);
    f(a, b, c, m, k, n);
    let mut times: Vec<f64> = (0..iters)
        .map(|_| {
            c.iter_mut().for_each(|v| *v = 0.0);
            let t0 = Instant::now();
            f(a, b, c, m, k, n);
            t0.elapsed().as_secs_f64() * 1000.0
        })
        .collect();
    std::hint::black_box(&c);
    times.sort_by(|x, y| x.partial_cmp(y).unwrap());
    times[times.len() / 2]
}

fn bench_shape(m: usize, k: usize, n: usize, iters: usize, rng: &mut Pcg64) -> Vec<Row> {
    let cases: [(&'static str, GemmFn, GemmFn, usize, usize); 3] = [
        // (op, blocked, reference, a_len, b_len)
        ("gemm", gemm::gemm, reference::gemm, m * k, k * n),
        ("gemm_nt", gemm::gemm_nt, reference::gemm_nt, m * k, n * k),
        ("gemm_tn", gemm::gemm_tn, reference::gemm_tn, k * m, k * n),
    ];
    let mut rows = Vec::new();
    for (op, blocked, refr, a_len, b_len) in cases {
        let a: Vec<f32> = (0..a_len).map(|_| rng.normal_f32()).collect();
        let b: Vec<f32> = (0..b_len).map(|_| rng.normal_f32()).collect();
        let mut c = vec![0.0f32; m * n];
        let blocked_ms = time_ms(blocked, &a, &b, &mut c, m, k, n, iters);
        let reference_ms = time_ms(refr, &a, &b, &mut c, m, k, n, iters.max(2));
        let row = Row { op, m, k, n, blocked_ms, reference_ms };
        println!(
            "{op:<8} {m:>5}x{k:<5}x{n:<5} blocked {blocked_ms:>9.2} ms ({:>6.1} GFLOP/s)  seed {reference_ms:>9.2} ms  speedup {:>5.2}x",
            row.gflops(),
            row.speedup()
        );
        rows.push(row);
    }
    rows
}

/// Bench `gemm_nt` with the B operand packed to 16-bit storage, per
/// format and per available SIMD level (scalar oracle always included,
/// so the JSON records the widening cost even on fast machines).
fn bench_half_shape(m: usize, k: usize, n: usize, iters: usize, rng: &mut Pcg64) -> Vec<HalfRow> {
    let a: Vec<f32> = (0..m * k).map(|_| rng.normal_f32()).collect();
    let bf: Vec<f32> = (0..n * k).map(|_| rng.normal_f32()).collect();
    let mut c = vec![0.0f32; m * n];
    let f32_ms = median_ms(iters, || {
        c.iter_mut().for_each(|v| *v = 0.0);
        gemm::gemm_nt_bias_q(&a, &bf, &mut c, m, k, n, None, Precision::Fp32);
    });
    let detected = simd::detect();
    let mut rows = Vec::new();
    for fmt in [HalfFormat::F16, HalfFormat::Bf16] {
        let mut b = vec![0u16; n * k];
        fmt.pack_slice(&bf, &mut b);
        let mut level_ms = Vec::new();
        for level in [simd::Level::Scalar, detected] {
            if level_ms.iter().any(|&(l, _)| l == level) {
                continue; // scalar machine: detected level IS the oracle
            }
            let ms = median_ms(iters, || {
                c.iter_mut().for_each(|v| *v = 0.0);
                gemm::gemm_nt_bias_q_half_at(level, &a, &b, fmt, &mut c, m, k, n, None, Precision::Fp32);
            });
            level_ms.push((level, ms));
        }
        std::hint::black_box(&c);
        let scalar_ms = level_ms[0].1;
        for (level, half_ms) in level_ms {
            let row = HalfRow { fmt: fmt.name(), level: level.name(), m, k, n, half_ms, f32_ms, scalar_ms };
            println!(
                "gemm_nt_half {:<4} {:<6} {m:>5}x{k:<5}x{n:<5} {half_ms:>9.2} ms  B {:>6.1} GB/s  vs f32 {:>5.2}x  vs scalar {:>5.2}x",
                row.fmt,
                row.level,
                row.b_gbs(),
                row.speedup_vs_f32(),
                row.speedup_vs_scalar()
            );
            rows.push(row);
        }
    }
    rows
}

type GemmAtFn =
    fn(simd::Level, &[f32], &[f32], &mut [f32], usize, usize, usize, Option<&[f32]>, Precision);

/// Bench the f32 SIMD compute plane: the three fused GEMM kernels and
/// the slice RNE quantizer, each pinned to the scalar oracle and to the
/// detected level, with an in-bench bitwise parity gate.
fn bench_simd_f32_shape(m: usize, k: usize, n: usize, iters: usize, rng: &mut Pcg64) -> Vec<SimdF32Row> {
    let detected = simd::detect();
    let cases: [(&'static str, GemmAtFn, usize, usize); 3] = [
        ("gemm", gemm::gemm_bias_q_at, m * k, k * n),
        ("gemm_nt", gemm::gemm_nt_bias_q_at, m * k, n * k),
        ("gemm_tn", gemm::gemm_tn_bias_q_at, k * m, k * n),
    ];
    let mut rows = Vec::new();
    for (op, f, a_len, b_len) in cases {
        let a: Vec<f32> = (0..a_len).map(|_| rng.normal_f32()).collect();
        let b: Vec<f32> = (0..b_len).map(|_| rng.normal_f32()).collect();
        let mut c = vec![0.0f32; m * n];
        // parity gate: the levels must agree bitwise before timing
        let mut oracle = vec![0.0f32; m * n];
        f(simd::Level::Scalar, &a, &b, &mut oracle, m, k, n, None, Precision::Fp32);
        f(detected, &a, &b, &mut c, m, k, n, None, Precision::Fp32);
        assert!(
            c.iter().zip(&oracle).all(|(x, y)| x.to_bits() == y.to_bits()),
            "{op} {m}x{k}x{n}: {} must equal the scalar oracle bitwise",
            detected.name()
        );
        let bytes = 4 * (a_len + b_len + m * n);
        let mut level_ms = Vec::new();
        for level in [simd::Level::Scalar, detected] {
            if level_ms.iter().any(|&(l, _)| l == level) {
                continue; // scalar machine: detected level IS the oracle
            }
            let ms = median_ms(iters, || {
                c.iter_mut().for_each(|v| *v = 0.0);
                f(level, &a, &b, &mut c, m, k, n, None, Precision::Fp32);
            });
            level_ms.push((level, ms));
        }
        std::hint::black_box(&c);
        let scalar_ms = level_ms[0].1;
        for (level, ms) in level_ms {
            let row =
                SimdF32Row { op, level: level.name(), m, k, n, ms, scalar_ms, bytes };
            println!(
                "simd_f32 {op:<8} {:<6} {m:>5}x{k:<5}x{n:<5} {ms:>9.2} ms  {:>6.1} GB/s  vs scalar {:>5.2}x",
                row.level,
                row.gbs(),
                row.speedup_vs_scalar()
            );
            rows.push(row);
        }
    }
    rows
}

/// Bench the slice RNE quantizer (the fp16-simulation hot loop) at the
/// scalar and detected levels over a learner-round-sized slice.
fn bench_simd_quantize(len: usize, iters: usize, rng: &mut Pcg64) -> Vec<SimdF32Row> {
    let detected = simd::detect();
    let base: Vec<f32> = (0..len).map(|_| rng.normal_f32()).collect();
    // parity gate
    let mut oracle = base.clone();
    simd::quantize_slice_rne_at(simd::Level::Scalar, 5, 10, &mut oracle);
    let mut fast = base.clone();
    simd::quantize_slice_rne_at(detected, 5, 10, &mut fast);
    assert!(
        fast.iter().zip(&oracle).all(|(x, y)| x.to_bits() == y.to_bits()),
        "quantize len={len}: {} must equal the scalar oracle bitwise",
        detected.name()
    );
    let mut rows = Vec::new();
    let mut level_ms = Vec::new();
    let mut xs = base.clone();
    for level in [simd::Level::Scalar, detected] {
        if level_ms.iter().any(|&(l, _)| l == level) {
            continue;
        }
        let ms = median_ms(iters, || {
            xs.copy_from_slice(&base);
            simd::quantize_slice_rne_at(level, 5, 10, &mut xs);
        });
        level_ms.push((level, ms));
    }
    std::hint::black_box(&xs);
    let scalar_ms = level_ms[0].1;
    for (level, ms) in level_ms {
        let row = SimdF32Row {
            op: "quantize_rne",
            level: level.name(),
            m: len,
            k: 0,
            n: 0,
            ms,
            scalar_ms,
            bytes: 8 * len, // read + write
        };
        println!(
            "simd_f32 {:<8} {:<6} len={len:<9} {ms:>9.3} ms  {:>6.1} GB/s  vs scalar {:>5.2}x",
            row.op,
            row.level,
            row.gbs(),
            row.speedup_vs_scalar()
        );
        rows.push(row);
    }
    rows
}

fn write_json(rows: &[Row], half: &[HalfRow], simd_f32: &[SimdF32Row]) -> std::io::Result<std::path::PathBuf> {
    let mut out = String::new();
    out.push_str("{\n  \"bench\": \"gemm\",\n  \"unit\": \"ms\",\n  \"shapes\": [\n");
    for (i, r) in rows.iter().enumerate() {
        let _ = write!(
            out,
            "    {{\"op\": \"{}\", \"m\": {}, \"k\": {}, \"n\": {}, \"blocked_ms\": {:.4}, \"reference_ms\": {:.4}, \"speedup\": {:.3}, \"blocked_gflops\": {:.2}}}",
            r.op, r.m, r.k, r.n, r.blocked_ms, r.reference_ms, r.speedup(), r.gflops()
        );
        out.push_str(if i + 1 < rows.len() { ",\n" } else { "\n" });
    }
    out.push_str("  ],\n");
    let _ = write!(out, "  \"cpu\": \"{}\"", simd::feature_summary());
    out.push_str(",\n");
    // half_storage[]: the bandwidth win — detected level vs the f32 path
    let detected = simd::detect().name();
    out.push_str("  \"half_storage\": [\n");
    let hs: Vec<&HalfRow> = half.iter().filter(|r| r.level == detected).collect();
    for (i, r) in hs.iter().enumerate() {
        let _ = write!(
            out,
            "    {{\"op\": \"gemm_nt_half\", \"fmt\": \"{}\", \"m\": {}, \"k\": {}, \"n\": {}, \"half_ms\": {:.4}, \"f32_ms\": {:.4}, \"speedup_vs_f32\": {:.3}, \"b_gbs\": {:.2}}}",
            r.fmt, r.m, r.k, r.n, r.half_ms, r.f32_ms, r.speedup_vs_f32(), r.b_gbs()
        );
        out.push_str(if i + 1 < hs.len() { ",\n" } else { "\n" });
    }
    out.push_str("  ],\n");
    // simd[]: every measured level, so the scalar-oracle cost is tracked
    out.push_str("  \"simd\": [\n");
    for (i, r) in half.iter().enumerate() {
        let _ = write!(
            out,
            "    {{\"fmt\": \"{}\", \"level\": \"{}\", \"m\": {}, \"k\": {}, \"n\": {}, \"ms\": {:.4}, \"speedup_vs_scalar\": {:.3}}}",
            r.fmt, r.level, r.m, r.k, r.n, r.half_ms, r.speedup_vs_scalar()
        );
        out.push_str(if i + 1 < half.len() { ",\n" } else { "\n" });
    }
    out.push_str("  ],\n");
    // simd_f32[]: the f32 compute plane — fused GEMM kernels + RNE
    // quantizer per level, parity-gated in this same bench run
    out.push_str("  \"simd_f32\": [\n");
    for (i, r) in simd_f32.iter().enumerate() {
        let _ = write!(
            out,
            "    {{\"op\": \"{}\", \"level\": \"{}\", \"m\": {}, \"k\": {}, \"n\": {}, \"ms\": {:.4}, \"gbs\": {:.2}, \"speedup_vs_scalar\": {:.3}}}",
            r.op, r.level, r.m, r.k, r.n, r.ms, r.gbs(), r.speedup_vs_scalar()
        );
        out.push_str(if i + 1 < simd_f32.len() { ",\n" } else { "\n" });
    }
    out.push_str("  ]\n}\n");
    // repo root = parent of the package dir
    let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .unwrap()
        .join("BENCH_gemm.json");
    std::fs::write(&path, out)?;
    Ok(path)
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--test");
    let mut rng = Pcg64::seed(1);
    let mut rows = Vec::new();
    println!("simd: {}", simd::feature_summary());
    if smoke {
        // CI smoke: exercise both the pooled and serial paths quickly,
        // plus the packed-half kernels at every available SIMD level
        println!("gemm bench smoke (--test): tiny shapes, no JSON");
        rows.extend(bench_shape(48, 64, 56, 2, &mut rng));
        rows.extend(bench_shape(130, 70, 90, 2, &mut rng));
        bench_half_shape(48, 64, 56, 2, &mut rng);
        bench_simd_f32_shape(48, 64, 56, 2, &mut rng);
        bench_simd_quantize(1 << 14, 2, &mut rng);
        return;
    }
    println!("blocked GEMM backend vs seed row-parallel scalar GEMM:");
    // SAC-sized hot shapes: hidden 1024, batch 512 (acceptance shape),
    // plus a mid-size shape closer to the scaled-down CPU configs.
    rows.extend(bench_shape(512, 1024, 1024, 5, &mut rng));
    rows.extend(bench_shape(256, 256, 256, 9, &mut rng));
    rows.extend(bench_shape(64, 1024, 1024, 5, &mut rng));
    println!("packed 16-bit B operand (half storage) vs blocked f32:");
    let mut half = Vec::new();
    half.extend(bench_half_shape(512, 1024, 1024, 5, &mut rng));
    half.extend(bench_half_shape(64, 1024, 1024, 5, &mut rng));
    println!("f32 SIMD compute plane vs scalar oracle (parity-gated):");
    let mut simd_f32 = Vec::new();
    simd_f32.extend(bench_simd_f32_shape(512, 1024, 1024, 5, &mut rng));
    simd_f32.extend(bench_simd_f32_shape(64, 1024, 1024, 5, &mut rng));
    simd_f32.extend(bench_simd_f32_shape(256, 256, 256, 9, &mut rng));
    simd_f32.extend(bench_simd_quantize(1 << 20, 9, &mut rng));
    match write_json(&rows, &half, &simd_f32) {
        Ok(p) => println!("wrote {}", p.display()),
        Err(e) => eprintln!("could not write BENCH_gemm.json: {e}"),
    }
    let worst = rows
        .iter()
        .filter(|r| r.m * r.k * r.n >= 512 * 1024 * 1024)
        .map(Row::speedup)
        .fold(f64::INFINITY, f64::min);
    println!("minimum speedup at SAC scale: {worst:.2}x (target >= 3x)");
}
