//! Bench: the blocked GEMM backend vs the seed's row-parallel scalar
//! GEMMs (`nn::gemm::reference`), at SAC-sized shapes. Writes the
//! results to `BENCH_gemm.json` at the repository root so the perf
//! trajectory is tracked from PR 1 onward.
//!
//! ```bash
//! cargo bench --bench gemm_blocked            # full run, writes JSON
//! cargo bench --bench gemm_blocked -- --test  # CI smoke: tiny shapes
//! ```

use lprl::nn::gemm::{self, reference};
use lprl::rngs::Pcg64;
use std::fmt::Write as _;
use std::time::Instant;

type GemmFn = fn(&[f32], &[f32], &mut [f32], usize, usize, usize);

struct Row {
    op: &'static str,
    m: usize,
    k: usize,
    n: usize,
    blocked_ms: f64,
    reference_ms: f64,
}

impl Row {
    fn speedup(&self) -> f64 {
        self.reference_ms / self.blocked_ms
    }

    fn gflops(&self) -> f64 {
        2.0 * (self.m * self.k * self.n) as f64 / (self.blocked_ms * 1e6)
    }
}

/// Median-of-iters wall time for one gemm call, in ms.
#[allow(clippy::too_many_arguments)]
fn time_ms(f: GemmFn, a: &[f32], b: &[f32], c: &mut [f32], m: usize, k: usize, n: usize, iters: usize) -> f64 {
    // warmup (also faults in the buffers)
    c.iter_mut().for_each(|v| *v = 0.0);
    f(a, b, c, m, k, n);
    let mut times: Vec<f64> = (0..iters)
        .map(|_| {
            c.iter_mut().for_each(|v| *v = 0.0);
            let t0 = Instant::now();
            f(a, b, c, m, k, n);
            t0.elapsed().as_secs_f64() * 1000.0
        })
        .collect();
    std::hint::black_box(&c);
    times.sort_by(|x, y| x.partial_cmp(y).unwrap());
    times[times.len() / 2]
}

fn bench_shape(m: usize, k: usize, n: usize, iters: usize, rng: &mut Pcg64) -> Vec<Row> {
    let cases: [(&'static str, GemmFn, GemmFn, usize, usize); 3] = [
        // (op, blocked, reference, a_len, b_len)
        ("gemm", gemm::gemm, reference::gemm, m * k, k * n),
        ("gemm_nt", gemm::gemm_nt, reference::gemm_nt, m * k, n * k),
        ("gemm_tn", gemm::gemm_tn, reference::gemm_tn, k * m, k * n),
    ];
    let mut rows = Vec::new();
    for (op, blocked, refr, a_len, b_len) in cases {
        let a: Vec<f32> = (0..a_len).map(|_| rng.normal_f32()).collect();
        let b: Vec<f32> = (0..b_len).map(|_| rng.normal_f32()).collect();
        let mut c = vec![0.0f32; m * n];
        let blocked_ms = time_ms(blocked, &a, &b, &mut c, m, k, n, iters);
        let reference_ms = time_ms(refr, &a, &b, &mut c, m, k, n, iters.max(2));
        let row = Row { op, m, k, n, blocked_ms, reference_ms };
        println!(
            "{op:<8} {m:>5}x{k:<5}x{n:<5} blocked {blocked_ms:>9.2} ms ({:>6.1} GFLOP/s)  seed {reference_ms:>9.2} ms  speedup {:>5.2}x",
            row.gflops(),
            row.speedup()
        );
        rows.push(row);
    }
    rows
}

fn write_json(rows: &[Row]) -> std::io::Result<std::path::PathBuf> {
    let mut out = String::new();
    out.push_str("{\n  \"bench\": \"gemm\",\n  \"unit\": \"ms\",\n  \"shapes\": [\n");
    for (i, r) in rows.iter().enumerate() {
        let _ = write!(
            out,
            "    {{\"op\": \"{}\", \"m\": {}, \"k\": {}, \"n\": {}, \"blocked_ms\": {:.4}, \"reference_ms\": {:.4}, \"speedup\": {:.3}, \"blocked_gflops\": {:.2}}}",
            r.op, r.m, r.k, r.n, r.blocked_ms, r.reference_ms, r.speedup(), r.gflops()
        );
        out.push_str(if i + 1 < rows.len() { ",\n" } else { "\n" });
    }
    out.push_str("  ]\n}\n");
    // repo root = parent of the package dir
    let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .unwrap()
        .join("BENCH_gemm.json");
    std::fs::write(&path, out)?;
    Ok(path)
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--test");
    let mut rng = Pcg64::seed(1);
    let mut rows = Vec::new();
    if smoke {
        // CI smoke: exercise both the pooled and serial paths quickly
        println!("gemm bench smoke (--test): tiny shapes, no JSON");
        rows.extend(bench_shape(48, 64, 56, 2, &mut rng));
        rows.extend(bench_shape(130, 70, 90, 2, &mut rng));
        return;
    }
    println!("blocked GEMM backend vs seed row-parallel scalar GEMM:");
    // SAC-sized hot shapes: hidden 1024, batch 512 (acceptance shape),
    // plus a mid-size shape closer to the scaled-down CPU configs.
    rows.extend(bench_shape(512, 1024, 1024, 5, &mut rng));
    rows.extend(bench_shape(256, 256, 256, 9, &mut rng));
    rows.extend(bench_shape(64, 1024, 1024, 5, &mut rng));
    match write_json(&rows) {
        Ok(p) => println!("wrote {}", p.display()),
        Err(e) => eprintln!("could not write BENCH_gemm.json: {e}"),
    }
    let worst = rows
        .iter()
        .filter(|r| r.m * r.k * r.n >= 512 * 1024 * 1024)
        .map(Row::speedup)
        .fold(f64::INFINITY, f64::min);
    println!("minimum speedup at SAC scale: {worst:.2}x (target >= 3x)");
}
