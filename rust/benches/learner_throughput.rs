//! Learner-throughput benchmark: gradient updates/sec of the SAC hot
//! loop after the PR-5 overhaul (pool-parallel optimizer, allocation-
//! free update rounds, fused target-side forwards).
//!
//! Two layers of measurement:
//!
//! * **micro** — the isolated learner loop (pre-filled replay → round
//!   arena → `SacAgent::update_round`), with a counting global
//!   allocator reporting steady-state heap allocations per update.
//!   Both the states and the pixels paths are fully allocation-free
//!   after warm-up — sampling, forwards (incl. conv im2col and the
//!   encoder walks), backwards, optimizer, EMA all reuse workspace
//!   buffers — and the bench asserts `allocs_per_update == 0` for
//!   every preset. A `half_storage` section times the same loops with
//!   the read-only weight tiers packed to 16 bits (SIMD widening
//!   GEMMs), which must also stay allocation-free;
//! * **train** — full `coordinator::train` runs (states + pixels,
//!   strict + async) reporting the `TrainOutcome` updates/sec next to
//!   collection throughput.
//!
//! Before timing anything the bench asserts the bitwise gates: fused
//! rounds vs per-update calls (states and pixels), and strict
//! `num_envs=1` seed-determinism.
//!
//! ```bash
//! cargo bench --bench learner_throughput            # full run, writes BENCH_learner.json
//! cargo bench --bench learner_throughput -- --test  # CI smoke: tiny, no JSON
//! ```

use lprl::config::RunConfig;
use lprl::coordinator::train;
use lprl::lowp::{HalfFormat, Precision};
use lprl::nn::Tensor;
use lprl::replay::{ReplayBuffer, RoundArena, Storage};
use lprl::rngs::Pcg64;
use lprl::sac::{Critic, Methods, SacAgent, SacConfig};
use std::alloc::{GlobalAlloc, Layout, System};
use std::fmt::Write as _;
use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Instant;

/// Counts every heap allocation so the bench can report steady-state
/// allocations per update.
struct CountingAlloc;

static ALLOCS: AtomicU64 = AtomicU64::new(0);

// SAFETY: a pure pass-through to `System` plus a relaxed counter bump —
// the allocator contract is exactly `System`'s.
unsafe impl GlobalAlloc for CountingAlloc {
    // SAFETY: callers uphold `GlobalAlloc::alloc`'s contract; the layout
    // is forwarded to `System` unchanged.
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        // SAFETY: same layout the caller vouched for.
        unsafe { System.alloc(layout) }
    }

    // SAFETY: callers pass a pointer this allocator returned with this
    // exact layout, which is what `System` requires.
    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        // SAFETY: `ptr`/`layout` come straight from the caller's contract.
        unsafe { System.dealloc(ptr, layout) }
    }

    // SAFETY: callers pass a live allocation of `layout` and a non-zero
    // `new_size`, forwarded to `System` unchanged.
    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        // SAFETY: `ptr`/`layout`/`new_size` come straight from the caller.
        unsafe { System.realloc(ptr, layout, new_size) }
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

fn preset(name: &str) -> (Methods, Precision) {
    match name {
        "fp32" => (Methods::none(), Precision::Fp32),
        "fp16_ours" => (Methods::ours(), Precision::fp16()),
        "fp16_naive" => (Methods::none(), Precision::fp16()),
        other => panic!("unknown preset {other}"),
    }
}

struct MicroShape {
    obs_dim: usize,
    act_dim: usize,
    hidden: usize,
    batch: usize,
    /// Updates per round (exercises the fused grouping when > 1).
    round: usize,
    pixels: bool,
    img: usize,
    filters: usize,
}

fn build_agent(name: &str, sh: &MicroShape, seed: u64) -> SacAgent {
    let (methods, prec) = preset(name);
    if sh.pixels {
        SacAgent::new_pixels(
            SacConfig::pixels(sh.obs_dim, sh.act_dim, sh.hidden),
            methods,
            prec,
            seed,
            3,
            sh.img,
            sh.filters,
        )
    } else {
        SacAgent::new(SacConfig::states(sh.obs_dim, sh.act_dim, sh.hidden), methods, prec, seed)
    }
}

fn fill_replay(sh: &MicroShape, storage: Storage, n: usize, rng: &mut Pcg64) -> ReplayBuffer {
    let obs_shape: Vec<usize> =
        if sh.pixels { vec![3, sh.img, sh.img] } else { vec![sh.obs_dim] };
    let mut replay = ReplayBuffer::new(n, &obs_shape, sh.act_dim, storage);
    let obs_len: usize = obs_shape.iter().product();
    let mut obs = vec![0.0f32; obs_len];
    let mut next = vec![0.0f32; obs_len];
    let mut act = vec![0.0f32; sh.act_dim];
    for _ in 0..n {
        for v in obs.iter_mut() {
            *v = if sh.pixels { rng.uniform_f32() } else { rng.normal_f32() };
        }
        for v in next.iter_mut() {
            *v = if sh.pixels { rng.uniform_f32() } else { rng.normal_f32() };
        }
        for v in act.iter_mut() {
            *v = rng.uniform_in(-1.0, 1.0);
        }
        replay.push(&obs, &act, rng.uniform_f32(), &next, false);
    }
    replay
}

struct MicroRow {
    preset: &'static str,
    obs: &'static str,
    /// Read-only weight tier: "f32", or a packed 16-bit format.
    storage: &'static str,
    batch: usize,
    hidden: usize,
    round: usize,
    updates_per_sec: f64,
    allocs_per_update: f64,
}

fn micro_bench(
    name: &'static str,
    sh: &MicroShape,
    rounds: usize,
    half: Option<HalfFormat>,
) -> MicroRow {
    let mut agent = build_agent(name, sh, 5);
    if let Some(fmt) = half {
        agent.set_half_storage(fmt);
    }
    let storage = if agent.compute.is_low() { Storage::F16 } else { Storage::F32 };
    let mut rng = Pcg64::seed(11);
    let replay = {
        let mut r = Pcg64::seed(23);
        fill_replay(sh, storage, 512.max(sh.batch * 2), &mut r)
    };
    let aug = if sh.pixels { Some(2) } else { None };
    let mut arena = RoundArena::default();
    // warm-up: fills every workspace/arena buffer
    for _ in 0..3 {
        replay.sample_round_into(sh.round, sh.batch, aug, &mut rng, &mut arena);
        agent.update_round(arena.batches());
    }
    let a0 = ALLOCS.load(Ordering::Relaxed);
    let t0 = Instant::now();
    for _ in 0..rounds {
        replay.sample_round_into(sh.round, sh.batch, aug, &mut rng, &mut arena);
        agent.update_round(arena.batches());
    }
    let secs = t0.elapsed().as_secs_f64();
    let allocs = ALLOCS.load(Ordering::Relaxed) - a0;
    let n_updates = (rounds * sh.round) as f64;
    MicroRow {
        preset: name,
        obs: if sh.pixels { "pixels" } else { "states" },
        storage: half.map_or("f32", HalfFormat::name),
        batch: sh.batch,
        hidden: sh.hidden,
        round: sh.round,
        updates_per_sec: n_updates / secs,
        allocs_per_update: allocs as f64 / n_updates,
    }
}

/// Bitwise gate: a fused round must equal per-update calls for the
/// paper's preset shapes. Mirrors the `learner_parity` integration test
/// so a bench run is self-validating.
fn assert_fused_parity(name: &'static str, sh: &MicroShape) {
    let mut a = build_agent(name, sh, 17);
    let mut b = build_agent(name, sh, 17);
    let storage = if a.compute.is_low() { Storage::F16 } else { Storage::F32 };
    let replay = {
        let mut r = Pcg64::seed(29);
        fill_replay(sh, storage, 128.max(sh.batch * 2), &mut r)
    };
    let aug = if sh.pixels { Some(2) } else { None };
    let mut r1 = Pcg64::seed(31);
    let mut r2 = Pcg64::seed(31);
    let mut arena = RoundArena::default();
    for _ in 0..4 {
        replay.sample_round_into(sh.round, sh.batch, aug, &mut r1, &mut arena);
        for bt in arena.batches() {
            a.update(bt);
        }
        let mut arena_b = RoundArena::default();
        replay.sample_round_into(sh.round, sh.batch, aug, &mut r2, &mut arena_b);
        b.update_round(arena_b.batches());
    }
    let (ca, cb) = (a.critic.flat_params(), b.critic.flat_params());
    assert!(
        ca.iter().zip(&cb).all(|(x, y)| x.to_bits() == y.to_bits()),
        "{name} fused round diverged from the per-update path"
    );
    let (ta, tb) = (a.target.flat_params(), b.target.flat_params());
    assert!(
        ta.iter().zip(&tb).all(|(x, y)| x.to_bits() == y.to_bits()),
        "{name} fused target diverged"
    );
    println!(
        "parity gate [{name} {}]: fused round bitwise == per-update  OK",
        if sh.pixels { "pixels" } else { "states" }
    );
}

struct PairRow {
    preset: &'static str,
    batch: usize,
    hidden: usize,
    paired_per_sec: f64,
    sequential_per_sec: f64,
}

/// Gate + time the paired twin-critic forward against two explicit head
/// forwards. The gate asserts bitwise identity per head; the timing pair
/// shows what halving the GEMM dispatches (6 → 3 per critic forward)
/// buys at this shape. Both loops include the `[obs | act]` join so the
/// comparison isolates the dispatch structure.
fn critic_pair_bench(
    preset: &'static str,
    prec: Precision,
    batch: usize,
    hidden: usize,
    iters: usize,
) -> PairRow {
    let mut rng = Pcg64::seed(41);
    let c = Critic::new("bench", 17, 6, hidden, &mut rng);
    let obs = Tensor::from_vec(&[batch, 17], (0..batch * 17).map(|_| rng.normal_f32()).collect());
    let act = Tensor::from_vec(&[batch, 6], (0..batch * 6).map(|_| rng.normal_f32()).collect());

    // bitwise gate: paired dispatch == two sequential head forwards
    let x = Critic::join(&obs, &act);
    let (s1, s2) = (c.q1.forward(&x, prec), c.q2.forward(&x, prec));
    let (q1, q2) = c.forward(&obs, &act, prec);
    assert!(
        q1.data.iter().zip(&s1.data).all(|(u, v)| u.to_bits() == v.to_bits())
            && q2.data.iter().zip(&s2.data).all(|(u, v)| u.to_bits() == v.to_bits()),
        "{preset} paired critic forward diverged from sequential heads"
    );

    let t0 = Instant::now();
    for _ in 0..iters {
        let _ = c.forward(&obs, &act, prec);
    }
    let paired_per_sec = iters as f64 / t0.elapsed().as_secs_f64();
    let t1 = Instant::now();
    for _ in 0..iters {
        let xi = Critic::join(&obs, &act);
        let _ = c.q1.forward(&xi, prec);
        let _ = c.q2.forward(&xi, prec);
    }
    let sequential_per_sec = iters as f64 / t1.elapsed().as_secs_f64();
    PairRow { preset, batch, hidden, paired_per_sec, sequential_per_sec }
}

struct TrainRow {
    preset: &'static str,
    obs: &'static str,
    mode: &'static str,
    num_envs: usize,
    updates_per_sec: f64,
    collect_sps: f64,
    wall_secs: f64,
}

struct SimdTrainRow {
    preset: &'static str,
    /// Dispatch level the leg ran at: "scalar" (forced) or the detected tier.
    simd: String,
    num_envs: usize,
    updates_per_sec: f64,
    collect_sps: f64,
}

/// Spawn `lprl train` in a child process so the scalar leg can force
/// `LPRL_SIMD=0` — the GEMM dispatch level is detected once per process,
/// so an in-process scalar row is impossible once any kernel has run.
/// Parses the trainer's `throughput:` summary line.
fn train_via_cli(
    preset: &'static str,
    steps: usize,
    hidden: usize,
    batch: usize,
    num_envs: usize,
    force_scalar: bool,
) -> SimdTrainRow {
    let exe = env!("CARGO_BIN_EXE_lprl");
    let out_dir = std::env::temp_dir().join(format!(
        "lprl-learner-simd-{}-{preset}-{}",
        std::process::id(),
        if force_scalar { "scalar" } else { "auto" }
    ));
    let mut cmd = std::process::Command::new(exe);
    cmd.arg("train");
    cmd.arg("task=pendulum_swingup");
    cmd.arg(format!("preset={preset}"));
    cmd.arg(format!("steps={steps}"));
    cmd.arg(format!("seed_steps={}", (steps / 8).max(num_envs)));
    cmd.arg(format!("batch={batch}"));
    cmd.arg(format!("hidden={hidden}"));
    cmd.arg(format!("eval_every={steps}"));
    cmd.arg("eval_episodes=1");
    cmd.arg(format!("num_envs={num_envs}"));
    cmd.arg(format!("out_dir={}", out_dir.display()));
    if force_scalar {
        cmd.env("LPRL_SIMD", "0");
    } else {
        cmd.env_remove("LPRL_SIMD");
    }
    let out = cmd.output().expect("failed to launch lprl train");
    assert!(
        out.status.success(),
        "lprl train {preset} (force_scalar={force_scalar}) failed:\n{}",
        String::from_utf8_lossy(&out.stderr)
    );
    let stdout = String::from_utf8_lossy(&out.stdout);
    let line = stdout
        .lines()
        .find(|l| l.starts_with("throughput:"))
        .expect("trainer printed no throughput line");
    let toks: Vec<&str> = line.split_whitespace().collect();
    let grab = |key: &str| -> f64 {
        let i = toks.iter().position(|t| *t == key).unwrap();
        toks[i + 1].parse().unwrap()
    };
    let collect_sps = grab("collect");
    let updates_per_sec = grab("learner");
    let _ = std::fs::remove_dir_all(&out_dir);
    SimdTrainRow {
        preset,
        simd: if force_scalar {
            "scalar".into()
        } else {
            lprl::nn::simd::detect().name().into()
        },
        num_envs,
        updates_per_sec,
        collect_sps,
    }
}

fn train_bench(
    name: &'static str,
    mode: &'static str,
    pixels: bool,
    num_envs: usize,
    steps: usize,
    hidden: usize,
    batch: usize,
) -> TrainRow {
    let mut cfg = RunConfig {
        task: "pendulum_swingup".into(),
        preset: name.into(),
        steps,
        seed_steps: (steps / 8).max(num_envs),
        batch,
        hidden,
        eval_every: steps, // single final eval, outside the update timer
        eval_episodes: 1,
        num_envs,
        sync_mode: mode.into(),
        ..Default::default()
    };
    if pixels {
        cfg.pixels = true;
        cfg.image_size = 21;
        cfg.filters = 8;
        cfg.feature_dim = 16;
        cfg.hidden = hidden.min(64);
        cfg.batch = batch.min(16);
    }
    let out = train(&cfg);
    assert!(!out.crashed, "{name} {mode} pixels={pixels} crashed");
    TrainRow {
        preset: name,
        obs: if pixels { "pixels" } else { "states" },
        mode,
        num_envs,
        updates_per_sec: out.updates_per_sec,
        collect_sps: out.collect_steps_per_sec,
        wall_secs: out.wall_secs,
    }
}

fn write_json(
    micro: &[MicroRow],
    half_rows: &[MicroRow],
    pairs: &[PairRow],
    trains: &[TrainRow],
    simd_rows: &[SimdTrainRow],
) -> std::io::Result<std::path::PathBuf> {
    let mut out = String::new();
    out.push_str("{\n  \"bench\": \"learner\",\n  \"task\": \"pendulum_swingup\",\n");
    out.push_str(
        "  \"gates\": {\"fused_parity\": \"bitwise\", \"strict_determinism\": true, \"critic_pair_parity\": \"bitwise\"},\n",
    );
    out.push_str("  \"critic_pair\": [\n");
    for (i, r) in pairs.iter().enumerate() {
        let _ = write!(
            out,
            "    {{\"preset\": \"{}\", \"batch\": {}, \"hidden\": {}, \"paired_fwd_per_sec\": {:.1}, \"sequential_fwd_per_sec\": {:.1}, \"speedup\": {:.3}}}",
            r.preset,
            r.batch,
            r.hidden,
            r.paired_per_sec,
            r.sequential_per_sec,
            r.paired_per_sec / r.sequential_per_sec
        );
        out.push_str(if i + 1 < pairs.len() { ",\n" } else { "\n" });
    }
    out.push_str("  ],\n  \"micro\": [\n");
    for (i, r) in micro.iter().enumerate() {
        let _ = write!(
            out,
            "    {{\"preset\": \"{}\", \"obs\": \"{}\", \"storage\": \"{}\", \"batch\": {}, \"hidden\": {}, \"round\": {}, \"updates_per_sec\": {:.2}, \"allocs_per_update\": {:.1}}}",
            r.preset, r.obs, r.storage, r.batch, r.hidden, r.round, r.updates_per_sec, r.allocs_per_update
        );
        out.push_str(if i + 1 < micro.len() { ",\n" } else { "\n" });
    }
    out.push_str("  ],\n  \"half_storage\": [\n");
    for (i, r) in half_rows.iter().enumerate() {
        let _ = write!(
            out,
            "    {{\"preset\": \"{}\", \"obs\": \"{}\", \"storage\": \"{}\", \"batch\": {}, \"hidden\": {}, \"round\": {}, \"updates_per_sec\": {:.2}, \"allocs_per_update\": {:.1}}}",
            r.preset, r.obs, r.storage, r.batch, r.hidden, r.round, r.updates_per_sec, r.allocs_per_update
        );
        out.push_str(if i + 1 < half_rows.len() { ",\n" } else { "\n" });
    }
    out.push_str("  ],\n  \"train\": [\n");
    for (i, r) in trains.iter().enumerate() {
        let _ = write!(
            out,
            "    {{\"preset\": \"{}\", \"obs\": \"{}\", \"mode\": \"{}\", \"num_envs\": {}, \"updates_per_sec\": {:.2}, \"collect_steps_per_sec\": {:.1}, \"wall_secs\": {:.3}}}",
            r.preset, r.obs, r.mode, r.num_envs, r.updates_per_sec, r.collect_sps, r.wall_secs
        );
        out.push_str(if i + 1 < trains.len() { ",\n" } else { "\n" });
    }
    out.push_str("  ],\n  \"simd_f32\": [\n");
    for (i, r) in simd_rows.iter().enumerate() {
        let _ = write!(
            out,
            "    {{\"preset\": \"{}\", \"simd\": \"{}\", \"num_envs\": {}, \"updates_per_sec\": {:.2}, \"collect_steps_per_sec\": {:.1}}}",
            r.preset, r.simd, r.num_envs, r.updates_per_sec, r.collect_sps
        );
        out.push_str(if i + 1 < simd_rows.len() { ",\n" } else { "\n" });
    }
    out.push_str("  ]\n}\n");
    let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .unwrap()
        .join("BENCH_learner.json");
    std::fs::write(&path, out)?;
    Ok(path)
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--test");
    println!("simd: {}", lprl::nn::simd::feature_summary());

    // -- correctness gates ------------------------------------------------
    let states_gate = MicroShape {
        obs_dim: 6,
        act_dim: 2,
        hidden: 24,
        batch: 8,
        round: 5,
        pixels: false,
        img: 0,
        filters: 0,
    };
    let pixels_gate = MicroShape {
        obs_dim: 8,
        act_dim: 2,
        hidden: 24,
        batch: 2,
        round: 3,
        pixels: true,
        img: 17,
        filters: 4,
    };
    for name in ["fp32", "fp16_ours", "fp16_naive"] {
        assert_fused_parity(name, &states_gate);
    }
    assert_fused_parity("fp16_ours", &pixels_gate);

    // -- paired twin-critic forward: gate + dispatch-halving timing -------
    let pair_iters = if smoke { 20 } else { 400 };
    let pairs = vec![
        critic_pair_bench("fp32", Precision::Fp32, 128, 256, pair_iters),
        critic_pair_bench("fp16_ours", Precision::fp16(), 128, 256, pair_iters),
    ];
    for r in &pairs {
        println!(
            "critic_pair {:>10} batch {:>3} hidden {:>3}: paired {:>8.1} fwd/s  sequential {:>8.1} fwd/s  ({:.2}x)",
            r.preset,
            r.batch,
            r.hidden,
            r.paired_per_sec,
            r.sequential_per_sec,
            r.paired_per_sec / r.sequential_per_sec
        );
    }

    // strict num_envs=1 determinism (the seed-trainer contract)
    let det_cfg = RunConfig {
        task: "pendulum_swingup".into(),
        preset: "fp16_ours".into(),
        steps: 48,
        seed_steps: 16,
        batch: 8,
        hidden: 24,
        eval_every: 24,
        eval_episodes: 1,
        ..Default::default()
    };
    let (d1, d2) = (train(&det_cfg), train(&det_cfg));
    assert_eq!(d1.eval_curve.points, d2.eval_curve.points, "strict run must be deterministic");
    println!("determinism gate [strict num_envs=1]: reruns match  OK");

    // -- micro: the isolated learner loop ---------------------------------
    let (micro_shapes, micro_rounds): (Vec<(&'static str, MicroShape)>, usize) = if smoke {
        (
            vec![
                ("fp32", MicroShape { obs_dim: 6, act_dim: 2, hidden: 32, batch: 16, round: 4, pixels: false, img: 0, filters: 0 }),
                ("fp16_ours", MicroShape { obs_dim: 6, act_dim: 2, hidden: 32, batch: 16, round: 4, pixels: false, img: 0, filters: 0 }),
                ("fp16_ours", MicroShape { obs_dim: 8, act_dim: 2, hidden: 24, batch: 4, round: 3, pixels: true, img: 17, filters: 4 }),
            ],
            10,
        )
    } else {
        (
            vec![
                ("fp32", MicroShape { obs_dim: 17, act_dim: 6, hidden: 256, batch: 128, round: 8, pixels: false, img: 0, filters: 0 }),
                ("fp16_ours", MicroShape { obs_dim: 17, act_dim: 6, hidden: 256, batch: 128, round: 8, pixels: false, img: 0, filters: 0 }),
                ("fp16_ours", MicroShape { obs_dim: 16, act_dim: 2, hidden: 64, batch: 16, round: 8, pixels: true, img: 21, filters: 8 }),
            ],
            40,
        )
    };
    let mut micro = Vec::new();
    for &(name, ref sh) in &micro_shapes {
        let row = micro_bench(name, sh, micro_rounds, None);
        println!(
            "micro {:>10} {:<6} batch {:>3} hidden {:>3} round {}: {:>9.1} upd/s  {:>7.1} allocs/upd",
            row.preset, row.obs, row.batch, row.hidden, row.round, row.updates_per_sec, row.allocs_per_update
        );
        // steady-state zero-allocation gate: states AND pixels — the
        // whole learner loop (conv im2col and the encoder walks
        // included) must not touch the heap once every buffer is warm
        assert_eq!(
            row.allocs_per_update, 0.0,
            "{name} {} learner loop allocated in steady state",
            row.obs
        );
        println!("alloc gate [{name} {}]: 0 allocs/update  OK", row.obs);
        micro.push(row);
    }

    // -- half_storage: the same loops with packed read-only weight tiers --
    let mut half_rows = Vec::new();
    for &(name, ref sh) in &micro_shapes {
        if name == "fp32" {
            continue; // the knob targets the low-precision presets
        }
        for fmt in [HalfFormat::F16, HalfFormat::Bf16] {
            if sh.pixels && fmt == HalfFormat::Bf16 {
                continue; // one format suffices for the slow conv path
            }
            let row = micro_bench(name, sh, micro_rounds, Some(fmt));
            println!(
                "half_storage {:>10} {:<6} [{}] batch {:>3} hidden {:>3} round {}: {:>9.1} upd/s  {:>7.1} allocs/upd",
                row.preset, row.obs, row.storage, row.batch, row.hidden, row.round,
                row.updates_per_sec, row.allocs_per_update
            );
            assert_eq!(
                row.allocs_per_update, 0.0,
                "{name} {} [{}] half-storage loop allocated in steady state",
                row.obs, row.storage
            );
            half_rows.push(row);
        }
    }

    // -- train: updates/sec inside the full trainer -----------------------
    let mut trains = Vec::new();
    if smoke {
        trains.push(train_bench("fp16_ours", "strict", false, 4, 64, 32, 16));
    } else {
        for name in ["fp32", "fp16_ours"] {
            for mode in ["strict", "async"] {
                trains.push(train_bench(name, mode, false, 8, 1500, 256, 128));
            }
        }
        for mode in ["strict", "async"] {
            trains.push(train_bench("fp16_ours", mode, true, 8, 256, 64, 16));
        }
    }
    for r in &trains {
        println!(
            "train {:>10} {:<6} {:>6} num_envs {}: learner {:>8.2} upd/s  collect {:>9.1} steps/s  wall {:>6.2}s",
            r.preset, r.obs, r.mode, r.num_envs, r.updates_per_sec, r.collect_sps, r.wall_secs
        );
    }

    // -- simd_f32: the same trainer, auto dispatch vs LPRL_SIMD=0 ---------
    let (sf_steps, sf_hidden, sf_batch, sf_envs) =
        if smoke { (64, 32, 16, 4) } else { (1500, 256, 128, 8) };
    let sf_presets: &[&'static str] = if smoke { &["fp32"] } else { &["fp32", "fp16_ours"] };
    let mut simd_rows = Vec::new();
    for &name in sf_presets {
        let auto = train_via_cli(name, sf_steps, sf_hidden, sf_batch, sf_envs, false);
        let scalar = train_via_cli(name, sf_steps, sf_hidden, sf_batch, sf_envs, true);
        println!(
            "simd_f32 train {:>10}: {} {:>8.2} upd/s  vs scalar {:>8.2} upd/s  ({:.2}x)",
            name,
            auto.simd,
            auto.updates_per_sec,
            scalar.updates_per_sec,
            auto.updates_per_sec / scalar.updates_per_sec
        );
        simd_rows.push(auto);
        simd_rows.push(scalar);
    }

    if smoke {
        println!("smoke mode: no JSON written");
        return;
    }
    match write_json(&micro, &half_rows, &pairs, &trains, &simd_rows) {
        Ok(p) => println!("wrote {}", p.display()),
        Err(e) => eprintln!("could not write BENCH_learner.json: {e}"),
    }
}
