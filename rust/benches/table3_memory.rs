//! Bench: regenerate the paper's Table 3 (pixels) and Table 11 (states)
//! memory sweeps at paper scale, plus measured replay-buffer bytes and
//! measured policy-snapshot bytes across the native storage tiers.

use lprl::lowp::{HalfFormat, Precision};
use lprl::replay::{ReplayBuffer, Storage};
use lprl::sac::{Methods, SacAgent, SacConfig};

fn main() -> anyhow::Result<()> {
    let kv: Vec<(String, String)> = vec![("seeds".into(), "1".into())];
    lprl::experiments::run("table3", &kv)?;
    println!();
    lprl::experiments::run("table11", &kv)?;

    // measured (not modeled) replay storage at paper scale
    println!("\nreplay buffer bytes (measured allocations, capacity 100k, pixel obs 9x84x84):");
    for (name, st) in [("fp32", Storage::F32), ("fp16", Storage::F16), ("u8  ", Storage::U8)] {
        let buf = ReplayBuffer::new(1000, &[9, 84, 84], 6, st);
        println!("  {name}: {:.1} MB per 1k transitions", buf.bytes() as f64 / 1e6);
    }

    // measured (not modeled) policy-snapshot resident bytes: f32 masters
    // vs the native 16-bit storage tier (packed weights, masters
    // dropped; only biases stay f32)
    println!("\npolicy snapshot resident weight bytes (measured, paper-scale nets):");
    let mut states =
        SacAgent::new(SacConfig::states(17, 6, 1024), Methods::ours(), Precision::fp16(), 1);
    let mut pixels = SacAgent::new_pixels(
        SacConfig::pixels(50, 6, 1024),
        Methods::ours(),
        Precision::fp16(),
        1,
        9,
        84,
        32,
    );
    for (name, agent) in [("states 17-d, hidden 1024", &mut states), ("pixels 9x84x84, 32 filt", &mut pixels)] {
        let f32_bytes = agent.policy().weight_bytes();
        for fmt in [HalfFormat::F16, HalfFormat::Bf16] {
            let mut snap = agent.policy();
            snap.pack_weights(fmt);
            let packed = snap.weight_bytes();
            println!(
                "  {name}: f32 {:>7.3} MB -> {:<4} {:>7.3} MB ({:.2}x smaller)",
                f32_bytes as f64 / 1e6,
                fmt.name(),
                packed as f64 / 1e6,
                f32_bytes as f64 / packed as f64
            );
        }
    }
    Ok(())
}
