//! Bench: regenerate the paper's Table 3 (pixels) and Table 11 (states)
//! memory sweeps at paper scale, plus measured replay-buffer bytes.

use lprl::replay::{ReplayBuffer, Storage};

fn main() -> anyhow::Result<()> {
    let kv: Vec<(String, String)> = vec![("seeds".into(), "1".into())];
    lprl::experiments::run("table3", &kv)?;
    println!();
    lprl::experiments::run("table11", &kv)?;

    // measured (not modeled) replay storage at paper scale
    println!("\nreplay buffer bytes (measured allocations, capacity 100k, pixel obs 9x84x84):");
    for (name, st) in [("fp32", Storage::F32), ("fp16", Storage::F16)] {
        let buf = ReplayBuffer::new(1000, &[9, 84, 84], 6, st);
        println!("  {name}: {:.1} MB per 1k transitions", buf.bytes() as f64 / 1e6);
    }
    Ok(())
}
