//! Bench: PJRT artifact execution — latency per fused train step and
//! per policy-inference call, for every variant. Measures the L3 hot
//! path of the three-layer architecture (host-copy overhead included).
//!
//! Requires AOT artifacts (`python python/compile/aot.py`); exits
//! cleanly when missing.

use lprl::rngs::Pcg64;
use lprl::runtime::TrainSession;
use std::time::Instant;

fn main() -> anyhow::Result<()> {
    if !std::path::Path::new("artifacts/manifest.txt").exists() {
        println!("skipping runtime bench: generate artifacts with `python python/compile/aot.py` first");
        return Ok(());
    }
    for variant in ["fp32", "fp16_naive", "fp16_ours"] {
        let t0 = Instant::now();
        let mut sess = TrainSession::new("artifacts", variant)?;
        let compile_s = t0.elapsed().as_secs_f64();
        let (o, a, b) = sess.dims();
        let mut rng = Pcg64::seed(1);
        let mut v = |n: usize| -> Vec<f32> { (0..n).map(|_| rng.normal_f32()).collect() };
        let (obs, act, next_obs) = (v(b * o), v(b * a), v(b * o));
        let (eps_n, eps_c) = (v(b * a), v(b * a));
        let rew = vec![0.5f32; b];
        let nd = vec![1.0f32; b];

        // warm
        for _ in 0..3 {
            sess.step(&obs, &act, &rew, &next_obs, &nd, &eps_n, &eps_c)?;
        }
        let iters = 50;
        let t0 = Instant::now();
        for _ in 0..iters {
            sess.step(&obs, &act, &rew, &next_obs, &nd, &eps_n, &eps_c)?;
        }
        let step_ms = t0.elapsed().as_secs_f64() * 1000.0 / iters as f64;

        let obs1 = v(o);
        let eps1 = v(a);
        for _ in 0..3 {
            sess.act(&obs1, &eps1)?;
        }
        let t0 = Instant::now();
        for _ in 0..200 {
            sess.act(&obs1, &eps1)?;
        }
        let act_us = t0.elapsed().as_secs_f64() * 1e6 / 200.0;

        println!(
            "{variant:<12} compile {compile_s:>5.1}s   train_step {step_ms:>7.3} ms ({:.0}/s)   act {act_us:>7.1} us",
            1000.0 / step_ms
        );
    }
    Ok(())
}
