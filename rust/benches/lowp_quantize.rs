//! Bench: quantization throughput — the single hottest operation in the
//! simulated-precision engine (every tensor op ends with a quantize
//! pass). Figure 4's sweep and all fp16 runs are bounded by this.

use lprl::lowp::{e5m, FloatFormat, OverflowMode, RoundMode, BF16, FP16};
use lprl::rngs::Pcg64;
use std::time::Instant;

fn bench_fmt(label: &str, fmt: FloatFormat, xs: &[f32], iters: usize) {
    let mut buf = xs.to_vec();
    // warmup
    fmt.quantize_slice(&mut buf);
    let t0 = Instant::now();
    for _ in 0..iters {
        buf.copy_from_slice(xs);
        fmt.quantize_slice(&mut buf);
    }
    let ns = t0.elapsed().as_nanos() as f64 / (iters * xs.len()) as f64;
    println!("{label:<28} {ns:>8.2} ns/elem");
    std::hint::black_box(&buf);
}

fn main() {
    let n = 1 << 18;
    let mut rng = Pcg64::seed(1);
    let xs: Vec<f32> = (0..n).map(|_| rng.normal_f32()).collect();
    let iters = 50;

    println!("quantize_slice throughput ({} elems):", n);
    bench_fmt("fp16 (e5m10)", FP16, &xs, iters);
    bench_fmt("bf16 (e8m7)", BF16, &xs, iters);
    bench_fmt("e5m7", e5m(7), &xs, iters);
    bench_fmt("e5m5", e5m(5), &xs, iters);

    // stochastic rounding (needs RNG per element)
    let mut buf = xs.clone();
    let mut r = Pcg64::seed(2);
    let t0 = Instant::now();
    for _ in 0..10 {
        for v in buf.iter_mut() {
            *v = FP16.quantize_with(*v, RoundMode::Stochastic, OverflowMode::Infinity, Some(&mut r));
        }
        buf.copy_from_slice(&xs);
    }
    let ns = t0.elapsed().as_nanos() as f64 / (10 * n) as f64;
    println!("{:<28} {ns:>8.2} ns/elem", "fp16 stochastic");
    std::hint::black_box(&buf);

    // subnormal-heavy input (the slow path)
    let tiny: Vec<f32> = (0..n).map(|_| rng.normal_f32() * 1e-6).collect();
    bench_fmt("fp16 on subnormal inputs", FP16, &tiny, iters);
}
