//! Bench: quantization throughput — the single hottest operation in the
//! simulated-precision engine (every tensor op ends with a quantize
//! pass). Figure 4's sweep and all fp16 runs are bounded by this.
//!
//! Also times the native 16-bit storage codecs (`HalfTensor`
//! pack/unpack, in GB/s of f32 moved): the storage tier's snapshot
//! publish and per-sync mirror refresh go through these, so their cost
//! bounds how often repacking can run.

use lprl::lowp::{e5m, FloatFormat, HalfFormat, HalfTensor, OverflowMode, RoundMode, BF16, FP16};
use lprl::rngs::Pcg64;
use std::time::Instant;

fn bench_fmt(label: &str, fmt: FloatFormat, xs: &[f32], iters: usize) {
    let mut buf = xs.to_vec();
    // warmup
    fmt.quantize_slice(&mut buf);
    let t0 = Instant::now();
    for _ in 0..iters {
        buf.copy_from_slice(xs);
        fmt.quantize_slice(&mut buf);
    }
    let ns = t0.elapsed().as_nanos() as f64 / (iters * xs.len()) as f64;
    println!("{label:<28} {ns:>8.2} ns/elem");
    std::hint::black_box(&buf);
}

fn main() {
    let n = 1 << 18;
    let mut rng = Pcg64::seed(1);
    let xs: Vec<f32> = (0..n).map(|_| rng.normal_f32()).collect();
    let iters = 50;

    println!("quantize_slice throughput ({} elems):", n);
    bench_fmt("fp16 (e5m10)", FP16, &xs, iters);
    bench_fmt("bf16 (e8m7)", BF16, &xs, iters);
    bench_fmt("e5m7", e5m(7), &xs, iters);
    bench_fmt("e5m5", e5m(5), &xs, iters);

    // stochastic rounding (needs RNG per element)
    let mut buf = xs.clone();
    let mut r = Pcg64::seed(2);
    let t0 = Instant::now();
    for _ in 0..10 {
        for v in buf.iter_mut() {
            *v = FP16.quantize_with(*v, RoundMode::Stochastic, OverflowMode::Infinity, Some(&mut r));
        }
        buf.copy_from_slice(&xs);
    }
    let ns = t0.elapsed().as_nanos() as f64 / (10 * n) as f64;
    println!("{:<28} {ns:>8.2} ns/elem", "fp16 stochastic");
    std::hint::black_box(&buf);

    // subnormal-heavy input (the slow path)
    let tiny: Vec<f32> = (0..n).map(|_| rng.normal_f32() * 1e-6).collect();
    bench_fmt("fp16 on subnormal inputs", FP16, &tiny, iters);

    // native 16-bit storage codecs: GB/s of f32 source moved per pack /
    // unpack pass (repack_from is the per-sync mirror-refresh path,
    // unpack_into the snapshot-decode path)
    println!("\nHalfTensor pack/unpack throughput ({} elems):", n);
    let src_bytes = (n * std::mem::size_of::<f32>()) as f64;
    let mut wide = vec![0.0f32; n];
    for fmt in [HalfFormat::F16, HalfFormat::Bf16] {
        let mut ht = HalfTensor::pack(fmt, &[n], &xs); // warmup + alloc
        let t0 = Instant::now();
        for _ in 0..iters {
            ht.repack_from(&xs);
        }
        let pack_gbs = src_bytes * iters as f64 / t0.elapsed().as_nanos() as f64;
        let t0 = Instant::now();
        for _ in 0..iters {
            ht.unpack_into(&mut wide);
        }
        let unpack_gbs = src_bytes * iters as f64 / t0.elapsed().as_nanos() as f64;
        println!("{:<28} pack {pack_gbs:>6.2} GB/s  unpack {unpack_gbs:>6.2} GB/s", fmt.name());
        std::hint::black_box((&ht, &wide));
    }
}
