//! Serve-layer benchmark: single-request vs micro-batched policy
//! inference, measured (a) directly against a `Policy` snapshot and
//! (b) end-to-end through the micro-batching `PolicyServer` (request
//! p50/p99 latency included). Writes `BENCH_serve.json` at the repo
//! root next to `BENCH_gemm.json`.
//!
//! ```bash
//! cargo bench --bench serve_throughput            # full run, writes JSON
//! cargo bench --bench serve_throughput -- --test  # CI smoke: tiny, no JSON
//! ```
//!
//! Before timing anything the bench asserts the serve-layer correctness
//! invariant: every row of a batch-32 `act_batch` is bitwise identical
//! to the batch-1 result for that observation.

use lprl::lowp::Precision;
use lprl::nn::Tensor;
use lprl::rngs::Pcg64;
use lprl::sac::{ActMode, Methods, Policy, SacAgent, SacConfig};
use lprl::serve::{NativeBackend, PolicyServer, ServeConfig};
use std::fmt::Write as _;
use std::sync::Arc;
use std::time::Instant;

struct DirectRow {
    batch: usize,
    per_req_us: f64,
    reqs_per_s: f64,
}

struct ServeRow {
    max_batch: usize,
    clients: usize,
    reqs_per_s: f64,
    mean_batch: f64,
    p50_us: u64,
    p99_us: u64,
}

/// Time `reps` sweeps over a fixed observation pool in chunks of `bsz`.
fn bench_direct(policy: &Policy, obs: &Tensor, bsz: usize, reps: usize) -> DirectRow {
    let obs_dim = policy.obs_len();
    let nobs = obs.rows();
    // warmup
    let _ = policy.act_batch(obs, ActMode::Deterministic);
    let t0 = Instant::now();
    for _ in 0..reps {
        let mut r0 = 0;
        while r0 < nobs {
            let b = bsz.min(nobs - r0);
            let chunk = Tensor::from_vec(
                &[b, obs_dim],
                obs.data[r0 * obs_dim..(r0 + b) * obs_dim].to_vec(),
            );
            std::hint::black_box(policy.act_batch(&chunk, ActMode::Deterministic));
            r0 += b;
        }
    }
    let secs = t0.elapsed().as_secs_f64();
    let total = (reps * nobs) as f64;
    DirectRow { batch: bsz, per_req_us: secs * 1e6 / total, reqs_per_s: total / secs }
}

/// Drive the server with `clients` threads issuing `reqs` requests each.
fn bench_serve(policy: &Policy, clients: usize, reqs: usize, max_batch: usize) -> ServeRow {
    let obs_dim = policy.obs_len();
    let server = PolicyServer::start(
        Arc::new(NativeBackend::new(policy.clone())),
        ServeConfig { max_batch, flush_us: 200, queue_cap: 4096, ..ServeConfig::default() },
    );
    let t0 = Instant::now();
    std::thread::scope(|s| {
        for c in 0..clients {
            let client = server.client();
            s.spawn(move || {
                let mut rng = Pcg64::seed_stream(42, c as u64);
                for _ in 0..reqs {
                    let obs: Vec<f32> = (0..obs_dim).map(|_| rng.normal_f32()).collect();
                    client.act(&obs).expect("serve request failed");
                }
            });
        }
    });
    let wall = t0.elapsed().as_secs_f64();
    let stats = server.shutdown();
    assert_eq!(stats.requests, (clients * reqs) as u64);
    ServeRow {
        max_batch,
        clients,
        reqs_per_s: stats.requests as f64 / wall,
        mean_batch: stats.mean_batch,
        p50_us: stats.p50_us,
        p99_us: stats.p99_us,
    }
}

fn write_json(
    dims: (usize, usize, usize),
    direct: &[DirectRow],
    serve: &[ServeRow],
    direct_speedup: f64,
    serve_speedup: f64,
) -> std::io::Result<std::path::PathBuf> {
    let mut out = String::new();
    out.push_str("{\n  \"bench\": \"serve\",\n");
    let _ = writeln!(
        out,
        "  \"policy\": {{\"obs_dim\": {}, \"act_dim\": {}, \"hidden\": {}, \"precision\": \"fp16\"}},",
        dims.0, dims.1, dims.2
    );
    out.push_str("  \"direct\": [\n");
    for (i, r) in direct.iter().enumerate() {
        let _ = write!(
            out,
            "    {{\"batch\": {}, \"per_req_us\": {:.3}, \"reqs_per_s\": {:.1}}}",
            r.batch, r.per_req_us, r.reqs_per_s
        );
        out.push_str(if i + 1 < direct.len() { ",\n" } else { "\n" });
    }
    out.push_str("  ],\n");
    let _ = writeln!(out, "  \"direct_speedup_batch32_vs_single\": {direct_speedup:.3},");
    out.push_str("  \"serve\": [\n");
    for (i, r) in serve.iter().enumerate() {
        let _ = write!(
            out,
            "    {{\"max_batch\": {}, \"clients\": {}, \"reqs_per_s\": {:.1}, \"mean_batch\": {:.2}, \"p50_us\": {}, \"p99_us\": {}}}",
            r.max_batch, r.clients, r.reqs_per_s, r.mean_batch, r.p50_us, r.p99_us
        );
        out.push_str(if i + 1 < serve.len() { ",\n" } else { "\n" });
    }
    out.push_str("  ],\n");
    let _ = writeln!(out, "  \"serve_speedup_batch32_vs_single\": {serve_speedup:.3}");
    out.push_str("}\n");
    let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .unwrap()
        .join("BENCH_serve.json");
    std::fs::write(&path, out)?;
    Ok(path)
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--test");
    // SAC-shaped policy: cheetah-ish obs, walker-ish act, mid paper-scale
    // trunk. The smoke config just exercises every path.
    let (obs_dim, act_dim, hidden) = if smoke { (8, 2, 32) } else { (60, 6, 512) };
    let agent = SacAgent::new(
        SacConfig::states(obs_dim, act_dim, hidden),
        Methods::ours(),
        Precision::fp16(),
        7,
    );
    let policy = agent.policy();

    let nobs = 32usize;
    let mut obs = Tensor::zeros(&[nobs, obs_dim]);
    Pcg64::seed(1).normal_fill(&mut obs.data);

    // -- correctness gate: batch rows == batch-1 results, bitwise -----
    let full = policy.act_batch(&obs, ActMode::Deterministic);
    for r in 0..nobs {
        let one = policy.act_batch(
            &Tensor::from_vec(&[1, obs_dim], obs.row(r).to_vec()),
            ActMode::Deterministic,
        );
        for (i, (x, y)) in one.data.iter().zip(full.row(r)).enumerate() {
            assert_eq!(
                x.to_bits(),
                y.to_bits(),
                "row {r} dim {i}: batch-1 {x} vs batch-32 {y}"
            );
        }
    }
    println!("bitwise parity: act_batch(32) rows == 32x act_batch(1)  OK");

    // -- direct policy throughput ------------------------------------
    let reps = if smoke { 3 } else { 200 };
    let mut direct = Vec::new();
    for &bsz in &[1usize, 8, 32] {
        let row = bench_direct(&policy, &obs, bsz, reps);
        println!(
            "direct  batch {:>2}: {:>9.1} req/s  ({:>7.2} us/req)",
            row.batch, row.reqs_per_s, row.per_req_us
        );
        direct.push(row);
    }
    let direct_speedup = direct.last().unwrap().reqs_per_s / direct[0].reqs_per_s;
    println!("direct micro-batch speedup (batch 32 vs single): {direct_speedup:.2}x");

    // -- through the serve layer -------------------------------------
    let (clients, reqs) = if smoke { (4, 8) } else { (32, 200) };
    let mut serve = Vec::new();
    for &mb in &[1usize, 32] {
        let row = bench_serve(&policy, clients, reqs, mb);
        println!(
            "serve   max_batch {:>2}: {:>9.1} req/s  mean_batch {:>5.2}  p50 {:>6} us  p99 {:>6} us",
            row.max_batch, row.reqs_per_s, row.mean_batch, row.p50_us, row.p99_us
        );
        serve.push(row);
    }
    let serve_speedup = serve.last().unwrap().reqs_per_s / serve[0].reqs_per_s;
    println!("serve micro-batch speedup (max_batch 32 vs 1): {serve_speedup:.2}x");

    if smoke {
        println!("smoke mode: no JSON written");
        return;
    }
    if direct_speedup < 4.0 {
        eprintln!("WARNING: direct micro-batch speedup {direct_speedup:.2}x below the 4x target");
    }
    match write_json((obs_dim, act_dim, hidden), &direct, &serve, direct_speedup, serve_speedup) {
        Ok(p) => println!("wrote {}", p.display()),
        Err(e) => eprintln!("could not write BENCH_serve.json: {e}"),
    }
}
