//! Bench: regenerate the paper's Table 2 (pixels) and Table 10 (states)
//! time-per-minibatch sweeps. `cargo bench --bench table2_states_speed`.
//!
//! Custom harness (the offline build has no criterion); timings use the
//! same warm-start + averaged-iterations protocol as the paper (§H).

fn main() -> anyhow::Result<()> {
    let kv: Vec<(String, String)> = vec![
        ("tasks".into(), "cheetah_run".into()),
        ("seeds".into(), "1".into()),
    ];
    lprl::experiments::run("table10", &kv)?;
    println!();
    lprl::experiments::run("table2", &kv)?;
    Ok(())
}
