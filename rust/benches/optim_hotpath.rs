//! Bench: the optimizer hot path — ns/element for Adam vs hAdam under
//! fp32 and simulated fp16, plus the Kahan EMA. These are the L3 kernels
//! the §Perf pass optimizes.

use lprl::lowp::Precision;
use lprl::nn::Param;
use lprl::optim::{Adam, AdamConfig, GradScaler, ScaledKahanEma, SecondMoment, UpdateMode};
use lprl::rngs::Pcg64;
use std::time::Instant;

fn bench<F: FnMut()>(label: &str, elems: usize, iters: usize, mut f: F) {
    // warmup
    f();
    let t0 = Instant::now();
    for _ in 0..iters {
        f();
    }
    let ns = t0.elapsed().as_nanos() as f64 / (iters * elems) as f64;
    println!("{label:<44} {ns:>8.2} ns/elem");
}

fn main() {
    let n = 1 << 16;
    let iters = 30;
    let mut rng = Pcg64::seed(1);
    let grads: Vec<f32> = (0..n).map(|_| rng.normal_f32() * 1e-3).collect();

    let cfg = AdamConfig::default();
    let cases: [(&str, Precision, SecondMoment, UpdateMode, bool); 5] = [
        ("adam fp32", Precision::Fp32, SecondMoment::Variance, UpdateMode::Plain, false),
        ("hadam fp32", Precision::Fp32, SecondMoment::Hypot, UpdateMode::Plain, false),
        ("adam fp16(sim)", Precision::fp16(), SecondMoment::Variance, UpdateMode::Plain, false),
        ("hadam fp16(sim)", Precision::fp16(), SecondMoment::Hypot, UpdateMode::Plain, false),
        ("hadam+kahan+compound fp16(sim) [paper]", Precision::fp16(), SecondMoment::Hypot, UpdateMode::Kahan, true),
    ];
    for (label, prec, second, update, compound) in cases {
        let mut opt = Adam::new(cfg, prec, second, update, compound);
        let mut p = Param::from_values("p", &[n], vec![0.1; n]);
        let mut sc = if compound { GradScaler::fixed(1e4) } else { GradScaler::disabled() };
        let gscale = sc.scale();
        bench(label, n, iters, || {
            for (g, src) in p.g.iter_mut().zip(&grads) {
                *g = src * gscale;
            }
            opt.step(&mut [&mut p], &mut sc);
        });
    }

    let psi: Vec<f32> = (0..n).map(|_| rng.normal_f32()).collect();
    for (label, prec, comp) in [
        ("target EMA plain fp32", Precision::Fp32, false),
        ("target EMA kahan-momentum fp16(sim)", Precision::fp16(), true),
    ] {
        let mut ema = ScaledKahanEma::new(&vec![0.0; n], 1e4, prec, comp);
        bench(label, n, iters, || ema.update(&psi, 0.005));
    }
}
