//! End-to-end integration over the native engine: short trainings across
//! presets, the crash-accounting path, and the experiment plumbing.

use lprl::config::RunConfig;
use lprl::coordinator::{run_many, train};
use lprl::envs::PLANET_TASKS;

fn quick(task: &str, preset: &str, steps: usize) -> RunConfig {
    RunConfig {
        task: task.into(),
        preset: preset.into(),
        steps,
        seed_steps: 60,
        batch: 16,
        hidden: 24,
        eval_every: steps,
        eval_episodes: 1,
        ..Default::default()
    }
}

#[test]
fn every_planet_task_trains_fp16_ours_without_crashing() {
    let cfgs: Vec<RunConfig> =
        PLANET_TASKS.iter().map(|t| quick(t, "fp16_ours", 100)).collect();
    let outs = run_many(&cfgs);
    for o in &outs {
        assert!(!o.crashed, "{} crashed", o.cfg.task);
        assert!(o.final_score.is_finite());
    }
}

#[test]
fn pendulum_fp32_learns_something() {
    let mut cfg = quick("pendulum_swingup", "fp32", 1200);
    cfg.hidden = 64;
    cfg.batch = 64;
    cfg.eval_every = 600;
    let out = train(&cfg);
    assert!(!out.crashed);
    // swing-up from scratch: after 1200 steps the return should clearly
    // beat the random-policy baseline (~5-40)
    assert!(
        out.final_score > 60.0,
        "fp32 should start learning: {}",
        out.final_score
    );
}

#[test]
fn pendulum_fp16_ours_learns_like_fp32() {
    let mut c32 = quick("pendulum_swingup", "fp32", 1200);
    c32.hidden = 64;
    c32.batch = 64;
    c32.eval_every = 600;
    let mut c16 = c32.clone();
    c16.preset = "fp16_ours".into();
    let outs = run_many(&[c32, c16]);
    assert!(!outs[0].crashed && !outs[1].crashed);
    let (f32_, f16_) = (outs[0].final_score, outs[1].final_score);
    assert!(f16_ > 0.35 * f32_, "fp16_ours {f16_} too far below fp32 {f32_}");
}

#[test]
fn all_ablation_presets_run() {
    let presets = ["cum0", "cum1", "cum2", "cum3", "cum4", "cum5", "cum6", "loo1", "loo6",
                   "coerc", "loss_scale", "mixed", "e5m7_ours", "bf16_ours"];
    let cfgs: Vec<RunConfig> =
        presets.iter().map(|p| quick("cartpole_swingup", p, 60)).collect();
    let outs = run_many(&cfgs);
    assert_eq!(outs.len(), presets.len());
    for o in &outs {
        // naive-ish presets may crash (that IS the phenomenon); the runs
        // must still terminate cleanly with a score
        assert!(o.final_score.is_finite(), "{}", o.cfg.preset);
    }
}

#[test]
fn vectorized_collection_trains_and_is_deterministic() {
    // the collector/learner loop over 4 lockstep env streams: must
    // complete, produce the same eval grid as the single-env trainer,
    // and be exactly reproducible in the seed
    let mut cfg = quick("pendulum_swingup", "fp16_ours", 120);
    cfg.eval_every = 60;
    cfg.seed_steps = 40;
    let single = train(&cfg);
    cfg.num_envs = 4;
    let a = train(&cfg);
    let b = train(&cfg);
    assert!(!a.crashed);
    assert_eq!(a.eval_curve.points, b.eval_curve.points, "N=4 reruns must match exactly");
    let xs = |o: &lprl::coordinator::TrainOutcome| {
        o.eval_curve.points.iter().map(|p| p.0).collect::<Vec<_>>()
    };
    assert_eq!(xs(&single), xs(&a), "eval step grid is num_envs-invariant");
    assert!(a.collect_steps_per_sec > 0.0 && a.updates_per_sec > 0.0);
}

#[test]
fn grad_probe_feeds_figure6() {
    let cfg = quick("cartpole_swingup", "fp32", 200);
    let out = train(&cfg);
    assert!(out.grad_hist.total() > 1000, "probe recorded {}", out.grad_hist.total());
    assert!(out.grad_hist.occupied_decades() >= 3.0);
}

#[test]
fn pixel_path_trains_fp16() {
    let mut cfg = quick("cartpole_swingup", "fp16_ours", 60);
    cfg.pixels = true;
    cfg.image_size = 17;
    cfg.filters = 4;
    cfg.feature_dim = 8;
    cfg.hidden = 16;
    cfg.batch = 4;
    cfg.seed_steps = 30;
    let out = train(&cfg);
    assert!(!out.crashed);
}
