//! Integration: the layers rewired through the blocked GEMM backend
//! (`Linear`, `Conv2d`, `Mlp`) must agree with a straightforward naive
//! implementation, and repeated/threaded execution must be bitwise
//! reproducible — the native engine's determinism guarantee.

use lprl::lowp::Precision;
use lprl::nn::{gemm, Conv2d, Linear, Mlp, MlpWorkspace, Tensor};
use lprl::rngs::Pcg64;

/// Naive `y = x Wᵀ + b` in f64 (PyTorch layout: w is `[out, in]`).
fn naive_linear(x: &Tensor, w: &[f32], b: &[f32], out_dim: usize) -> Vec<f32> {
    let (bsz, in_dim) = (x.rows(), x.cols());
    let mut y = vec![0.0f32; bsz * out_dim];
    for r in 0..bsz {
        for o in 0..out_dim {
            let mut acc = 0.0f64;
            for i in 0..in_dim {
                acc += x.data[r * in_dim + i] as f64 * w[o * in_dim + i] as f64;
            }
            y[r * out_dim + o] = (acc + b[o] as f64) as f32;
        }
    }
    y
}

#[test]
fn linear_forward_matches_naive_oracle() {
    let mut rng = Pcg64::seed(1);
    for &(bsz, in_dim, out_dim) in &[(1, 1, 1), (3, 7, 5), (33, 20, 17), (130, 65, 40)] {
        let lin = Linear::new("t", in_dim, out_dim, &mut rng);
        let x = Tensor::from_vec(
            &[bsz, in_dim],
            (0..bsz * in_dim).map(|_| rng.normal_f32()).collect(),
        );
        let y = lin.forward(&x, Precision::Fp32);
        let want = naive_linear(&x, &lin.w.w, &lin.b.w, out_dim);
        for (i, (a, b)) in y.data.iter().zip(&want).enumerate() {
            assert!(
                (a - b).abs() < 1e-3 * (1.0 + b.abs()),
                "{bsz}x{in_dim}x{out_dim} [{i}]: {a} vs {b}"
            );
        }
    }
}

#[test]
fn linear_forward_is_bitwise_reproducible() {
    // exercises the pooled path (batch x dims large enough to fan out)
    let mut rng = Pcg64::seed(2);
    let lin = Linear::new("t", 128, 96, &mut rng);
    let x = Tensor::from_vec(&[200, 128], (0..200 * 128).map(|_| rng.normal_f32()).collect());
    let y1 = lin.forward(&x, Precision::fp16());
    let y2 = lin.forward(&x, Precision::fp16());
    assert!(
        y1.data.iter().zip(&y2.data).all(|(a, b)| a.to_bits() == b.to_bits()),
        "threaded forward must be deterministic"
    );
}

#[test]
fn linear_fp16_output_is_representable() {
    let mut rng = Pcg64::seed(3);
    let lin = Linear::new("t", 40, 24, &mut rng);
    let x = Tensor::from_vec(&[9, 40], (0..360).map(|_| rng.normal_f32()).collect());
    let y = lin.forward(&x, Precision::fp16());
    for &v in &y.data {
        assert!(lprl::lowp::FP16.is_representable(v), "{v}");
    }
}

#[test]
fn conv_forward_matches_direct_convolution() {
    let mut rng = Pcg64::seed(4);
    let (b, cin, cout, h, w, k, stride) = (2, 3, 5, 9, 9, 3, 2);
    let conv = Conv2d::new("c", cin, cout, k, stride, &mut rng);
    let x = Tensor::from_vec(
        &[b, cin, h, w],
        (0..b * cin * h * w).map(|_| rng.normal_f32()).collect(),
    );
    let y = conv.forward(&x, Precision::Fp32);
    let (ho, wo) = conv.out_hw(h, w);
    assert_eq!(y.shape, vec![b, cout, ho, wo]);
    // direct f64 convolution
    for bi in 0..b {
        for co in 0..cout {
            for oy in 0..ho {
                for ox in 0..wo {
                    let mut acc = conv.b.w[co] as f64;
                    for ci in 0..cin {
                        for ky in 0..k {
                            for kx in 0..k {
                                let iy = oy * stride + ky;
                                let ix = ox * stride + kx;
                                let xv = x.data[((bi * cin + ci) * h + iy) * w + ix] as f64;
                                let wv =
                                    conv.w.w[co * cin * k * k + (ci * k + ky) * k + kx] as f64;
                                acc += xv * wv;
                            }
                        }
                    }
                    let got = y.data[((bi * cout + co) * ho + oy) * wo + ox];
                    assert!(
                        (got as f64 - acc).abs() < 1e-3 * (1.0 + acc.abs()),
                        "b={bi} co={co} ({oy},{ox}): {got} vs {acc}"
                    );
                }
            }
        }
    }
}

#[test]
fn mlp_forward_backward_still_gradchecks_through_backend() {
    // end-to-end through Linear + ReLU with the blocked backend
    let mut rng = Pcg64::seed(5);
    let mut mlp = Mlp::new("m", &[6, 48, 48, 3], &mut rng);
    let x = Tensor::from_vec(&[4, 6], (0..24).map(|_| rng.normal_f32()).collect());
    let prec = Precision::Fp32;
    let mut ws = MlpWorkspace::default();
    let y = mlp.forward_train(&x, prec, &mut ws);
    mlp.zero_grad();
    let dx = mlp.backward(&y.clone(), prec, &ws);

    let eps = 1e-3f32;
    let loss = |m: &Mlp, x: &Tensor| -> f32 {
        m.forward(x, prec).data.iter().map(|v| v * v / 2.0).sum()
    };
    let mut x2 = x.clone();
    for idx in [0usize, 5, 11, 23] {
        let o = x2.data[idx];
        x2.data[idx] = o + eps;
        let lp = loss(&mlp, &x2);
        x2.data[idx] = o - eps;
        let lm = loss(&mlp, &x2);
        x2.data[idx] = o;
        let num = (lp - lm) / (2.0 * eps);
        assert!(
            (num - dx.data[idx]).abs() < 2e-2 * (1.0 + num.abs()),
            "x[{idx}]: {num} vs {}",
            dx.data[idx]
        );
    }
}

#[test]
fn raw_gemm_entry_points_accumulate_like_seed() {
    // public wrappers keep the seed's `c +=` contract
    let mut rng = Pcg64::seed(6);
    let (m, k, n) = (10, 12, 8);
    let a: Vec<f32> = (0..m * k).map(|_| rng.normal_f32()).collect();
    let b: Vec<f32> = (0..k * n).map(|_| rng.normal_f32()).collect();
    let mut c0 = vec![0.5f32; m * n];
    gemm::gemm(&a, &b, &mut c0, m, k, n);
    let mut c1 = vec![0.0f32; m * n];
    gemm::gemm(&a, &b, &mut c1, m, k, n);
    for (x, y) in c0.iter().zip(&c1) {
        assert!((x - (y + 0.5)).abs() < 1e-5, "{x} vs {}", y + 0.5);
    }
}
