//! Storage-tier contracts (INVARIANTS.md "Native half storage & SIMD"):
//!
//! * the SIMD widening GEMM kernels are **bitwise equal** to the scalar
//!   oracle at every runtime feature level, shape, and format;
//! * the packed-half GEMM equals the f32 GEMM run on the decoded
//!   weights (so swapping a layer's storage tier is invisible);
//! * pack → unpack is exact on store-quantized values — the fp16 store
//!   writes onto the f16 grid, so packing target mirrors and snapshots
//!   loses nothing;
//! * a policy snapshot packed to 16-bit storage serves bitwise
//!   identical actions while holding roughly half the weight bytes.

use lprl::lowp::{HalfFormat, Precision, BF16, FP16};
use lprl::nn::gemm::{gemm_nt_bias_q, gemm_nt_bias_q_half, gemm_nt_bias_q_half_at};
use lprl::nn::{simd, Tensor};
use lprl::rngs::Pcg64;
use lprl::sac::{ActMode, Batch, Methods, SacAgent, SacConfig};

const SHAPES: &[(usize, usize, usize)] =
    &[(1, 1, 1), (2, 3, 5), (4, 16, 16), (5, 17, 33), (16, 64, 48), (33, 40, 19)];

fn fill(rng: &mut Pcg64, len: usize) -> Vec<f32> {
    (0..len).map(|_| rng.normal_f32()).collect()
}

#[test]
fn half_gemm_matches_scalar_oracle_across_shapes_and_levels() {
    let detected = simd::detect();
    println!("parity gate: {}", simd::feature_summary());
    let mut rng = Pcg64::seed(11);
    for fmt in [HalfFormat::F16, HalfFormat::Bf16] {
        for &(m, k, n) in SHAPES {
            let a = fill(&mut rng, m * k);
            let bf = fill(&mut rng, n * k);
            let mut b = vec![0u16; n * k];
            fmt.pack_slice(&bf, &mut b);
            let bias = fill(&mut rng, n);
            for prec in [Precision::Fp32, Precision::fp16()] {
                let mut oracle = vec![0.0f32; m * n];
                gemm_nt_bias_q_half_at(
                    simd::Level::Scalar,
                    &a,
                    &b,
                    fmt,
                    &mut oracle,
                    m,
                    k,
                    n,
                    Some(&bias),
                    prec,
                );
                let mut fast = vec![0.0f32; m * n];
                gemm_nt_bias_q_half_at(
                    detected, &a, &b, fmt, &mut fast, m, k, n, Some(&bias), prec,
                );
                assert!(
                    fast.iter().zip(&oracle).all(|(x, y)| x.to_bits() == y.to_bits()),
                    "{} {} {m}x{k}x{n}: vector path must equal the scalar oracle",
                    detected.name(),
                    fmt.name()
                );
                // the public auto-dispatch entry lands on the same bits
                let mut auto = vec![0.0f32; m * n];
                gemm_nt_bias_q_half(&a, &b, fmt, &mut auto, m, k, n, Some(&bias), prec);
                assert!(auto.iter().zip(&oracle).all(|(x, y)| x.to_bits() == y.to_bits()));
            }
        }
    }
}

// parity: gemm_nt_bias_q_pair_half — the fused critic pair is pinned by
// the half-storage bitwise tests in `sac::agent` (packed target critics
// run the pair entry and must match the plain f32 run exactly).

#[test]
fn half_gemm_equals_f32_gemm_on_decoded_weights() {
    let mut rng = Pcg64::seed(23);
    for fmt in [HalfFormat::F16, HalfFormat::Bf16] {
        for &(m, k, n) in SHAPES {
            let a = fill(&mut rng, m * k);
            let bf = fill(&mut rng, n * k);
            let mut b = vec![0u16; n * k];
            fmt.pack_slice(&bf, &mut b);
            let mut decoded = vec![0.0f32; n * k];
            fmt.unpack_slice(&b, &mut decoded);
            let bias = fill(&mut rng, n);
            for prec in [Precision::Fp32, Precision::fp16()] {
                let mut c_f32 = vec![0.0f32; m * n];
                gemm_nt_bias_q(&a, &decoded, &mut c_f32, m, k, n, Some(&bias), prec);
                let mut c_half = vec![0.0f32; m * n];
                gemm_nt_bias_q_half(&a, &b, fmt, &mut c_half, m, k, n, Some(&bias), prec);
                assert!(
                    c_half.iter().zip(&c_f32).all(|(x, y)| x.to_bits() == y.to_bits()),
                    "{} {m}x{k}x{n}: storage tier must be invisible given equal weights",
                    fmt.name()
                );
            }
        }
    }
}

#[test]
fn pack_roundtrip_is_exact_on_store_quantized_values() {
    let mut rng = Pcg64::seed(31);
    // random values snapped onto each format's grid, the way the fp16 /
    // bf16 stores write parameters, plus the edge cases
    let mut base: Vec<f32> = (0..4096).map(|_| rng.normal_f32() * 8.0).collect();
    base.extend((0..512).map(|_| rng.normal_f32() * 1e-6)); // subnormal range
    base.extend([0.0, -0.0, f32::INFINITY, f32::NEG_INFINITY, 65504.0, -65504.0]);
    for (fmt, grid) in [(HalfFormat::F16, FP16), (HalfFormat::Bf16, BF16)] {
        let mut xs = base.clone();
        grid.quantize_slice(&mut xs);
        let mut packed = vec![0u16; xs.len()];
        fmt.pack_slice(&xs, &mut packed);
        let mut back = vec![0.0f32; xs.len()];
        fmt.unpack_slice(&packed, &mut back);
        for (i, (x, y)) in xs.iter().zip(&back).enumerate() {
            assert_eq!(
                x.to_bits(),
                y.to_bits(),
                "{} elem {i}: {x} failed to round-trip through 16-bit storage",
                fmt.name()
            );
        }
    }
}

fn toy_batch(rng: &mut Pcg64, b: usize, obs_dim: usize, act_dim: usize) -> Batch {
    let mut obs = Tensor::zeros(&[b, obs_dim]);
    rng.normal_fill(&mut obs.data);
    let mut next_obs = Tensor::zeros(&[b, obs_dim]);
    rng.normal_fill(&mut next_obs.data);
    let mut act = Tensor::zeros(&[b, act_dim]);
    for v in act.data.iter_mut() {
        *v = rng.uniform_in(-1.0, 1.0);
    }
    Batch {
        obs,
        act,
        rew: (0..b).map(|_| rng.normal_f32() * 0.1).collect(),
        next_obs,
        not_done: vec![1.0; b],
    }
}

#[test]
fn packed_states_policy_serves_identical_actions_in_half_the_bytes() {
    let mut rng = Pcg64::seed(41);
    let cfg = SacConfig::states(6, 2, 32);
    let mut agent = SacAgent::new(cfg, Methods::ours(), Precision::fp16(), 5);
    for _ in 0..6 {
        let b = toy_batch(&mut rng, 8, 6, 2);
        agent.update(&b);
    }
    let plain = agent.policy();
    let mut packed = agent.policy();
    packed.pack_weights(HalfFormat::F16);
    assert!(
        packed.weight_bytes() < plain.weight_bytes() * 3 / 4,
        "packed {} vs f32 {}",
        packed.weight_bytes(),
        plain.weight_bytes()
    );
    let mut obs = Tensor::zeros(&[5, 6]);
    rng.normal_fill(&mut obs.data);
    let a = plain.act_batch(&obs, ActMode::Deterministic);
    let b = packed.act_batch(&obs, ActMode::Deterministic);
    assert!(a.data.iter().zip(&b.data).all(|(x, y)| x.to_bits() == y.to_bits()));
    let mut r1 = Pcg64::seed(9);
    let mut r2 = Pcg64::seed(9);
    let a = plain.act_batch(&obs, ActMode::Sample(&mut r1));
    let b = packed.act_batch(&obs, ActMode::Sample(&mut r2));
    assert!(a.data.iter().zip(&b.data).all(|(x, y)| x.to_bits() == y.to_bits()));
}

#[test]
fn packed_pixels_policy_serves_identical_actions() {
    let mut rng = Pcg64::seed(43);
    let cfg = SacConfig::pixels(8, 2, 24);
    let mut agent = SacAgent::new_pixels(cfg, Methods::ours(), Precision::fp16(), 9, 3, 21, 4);
    let plain = agent.policy();
    let mut packed = agent.policy();
    packed.pack_weights(HalfFormat::F16);
    assert!(packed.weight_bytes() < plain.weight_bytes() * 3 / 4);
    let mut obs = Tensor::zeros(&[2, 3, 21, 21]);
    for v in obs.data.iter_mut() {
        *v = rng.uniform_f32();
    }
    let a = plain.act_batch(&obs, ActMode::Deterministic);
    let b = packed.act_batch(&obs, ActMode::Deterministic);
    assert!(a.data.iter().zip(&b.data).all(|(x, y)| x.to_bits() == y.to_bits()));
}
