//! Learner hot-path contracts (PR 5): the pooled optimizer, the
//! pre-sampled round arena and the fused target-side forwards must all
//! be bitwise-identical to the legacy per-update path, for every preset
//! shape the paper runs — states and pixels, fp32, fp16_ours and
//! fp16_naive.

use lprl::lowp::Precision;
use lprl::nn::Tensor;
use lprl::replay::{ReplayBuffer, RoundArena, Storage};
use lprl::rngs::Pcg64;
use lprl::sac::{Batch, Methods, SacAgent, SacConfig};

/// The preset grid the parity tests sweep.
fn presets() -> Vec<(&'static str, Methods, Precision)> {
    vec![
        ("fp32", Methods::none(), Precision::Fp32),
        ("fp16_ours", Methods::ours(), Precision::fp16()),
        ("fp16_naive", Methods::none(), Precision::fp16()),
    ]
}

fn build_states(methods: Methods, prec: Precision) -> SacAgent {
    SacAgent::new(SacConfig::states(6, 2, 24), methods, prec, 17)
}

fn build_pixels(methods: Methods, prec: Precision) -> SacAgent {
    SacAgent::new_pixels(SacConfig::pixels(8, 2, 24), methods, prec, 17, 3, 21, 4)
}

fn states_batch(b: usize, rng: &mut Pcg64) -> Batch {
    let mut obs = Tensor::zeros(&[b, 6]);
    rng.normal_fill(&mut obs.data);
    let mut next_obs = Tensor::zeros(&[b, 6]);
    rng.normal_fill(&mut next_obs.data);
    let mut act = Tensor::zeros(&[b, 2]);
    for v in act.data.iter_mut() {
        *v = rng.uniform_in(-1.0, 1.0);
    }
    Batch {
        obs,
        act,
        rew: (0..b).map(|_| rng.uniform_f32()).collect(),
        next_obs,
        not_done: vec![1.0; b],
    }
}

fn pixels_batch(b: usize, rng: &mut Pcg64) -> Batch {
    let mut obs = Tensor::zeros(&[b, 3, 21, 21]);
    for v in obs.data.iter_mut() {
        *v = rng.uniform_f32();
    }
    let mut next_obs = obs.clone();
    for v in next_obs.data.iter_mut() {
        *v = (*v + 0.01).min(1.0);
    }
    let mut act = Tensor::zeros(&[b, 2]);
    for v in act.data.iter_mut() {
        *v = rng.uniform_in(-1.0, 1.0);
    }
    Batch {
        obs,
        act,
        rew: (0..b).map(|_| rng.uniform_f32()).collect(),
        next_obs,
        not_done: vec![1.0; b],
    }
}

fn assert_agents_bitwise_equal(a: &mut SacAgent, b: &mut SacAgent, label: &str) {
    assert_eq!(a.updates, b.updates, "{label}: update counters");
    let pairs = [
        (a.critic.flat_params(), b.critic.flat_params(), "critic"),
        (a.target.flat_params(), b.target.flat_params(), "target"),
    ];
    for (x, y, what) in &pairs {
        assert_eq!(x.len(), y.len());
        for (i, (u, v)) in x.iter().zip(y.iter()).enumerate() {
            assert_eq!(u.to_bits(), v.to_bits(), "{label}: {what}[{i}]");
        }
    }
    for (la, lb) in a.actor.params_mut().iter().zip(b.actor.params_mut().iter()) {
        assert!(
            la.w.iter().zip(&lb.w).all(|(u, v)| u.to_bits() == v.to_bits()),
            "{label}: actor weights"
        );
    }
    if let (Some(ea), Some(eb)) = (a.encoder.as_mut(), b.encoder.as_mut()) {
        let (fa, fb) = (ea.flat_params(), eb.flat_params());
        assert!(
            fa.iter().zip(&fb).all(|(u, v)| u.to_bits() == v.to_bits()),
            "{label}: encoder weights"
        );
    }
    if let (Some(ta), Some(tb)) = (a.target_encoder.as_mut(), b.target_encoder.as_mut()) {
        let (fa, fb) = (ta.flat_params(), tb.flat_params());
        assert!(
            fa.iter().zip(&fb).all(|(u, v)| u.to_bits() == v.to_bits()),
            "{label}: target-encoder weights"
        );
    }
    assert_eq!(
        a.log_alpha.w[0].to_bits(),
        b.log_alpha.w[0].to_bits(),
        "{label}: log_alpha"
    );
    assert_eq!(
        a.rng.clone().next_u64(),
        b.rng.clone().next_u64(),
        "{label}: agent RNG position"
    );
}

/// Fused round updates vs one-at-a-time updates on identical batch
/// streams: the whole agent state must match bitwise, for every preset.
///
/// This pins the fused hot path end-to-end against the sequential
/// reference (tracked by the lprl-tidy parity pass):
// parity: fuse_group — batch-group fusion inside the update round
// parity: forward_pair, forward_train_pair — fused critic-pair forwards
// parity: run_spans, run_chunked — pooled optimizer spans and chunked gemm claiming
#[test]
fn fused_rounds_match_sequential_updates_across_presets() {
    for pixels in [false, true] {
        for (name, methods, prec) in presets() {
            let (mut a, mut b) = if pixels {
                (build_pixels(methods, prec), build_pixels(methods, prec))
            } else {
                (build_states(methods, prec), build_states(methods, prec))
            };
            let mut rng = Pcg64::seed(71);
            let (bsz, rounds, per_round) = if pixels { (2, 3, 3) } else { (8, 4, 5) };
            for _ in 0..rounds {
                let batches: Vec<Batch> = (0..per_round)
                    .map(|_| if pixels { pixels_batch(bsz, &mut rng) } else { states_batch(bsz, &mut rng) })
                    .collect();
                for bt in &batches {
                    a.update(bt);
                }
                b.update_round(&batches);
            }
            let label = format!("{name} pixels={pixels}");
            assert_agents_bitwise_equal(&mut a, &mut b, &label);
        }
    }
}

/// The round arena path end to end: sampling a round up front and
/// updating through `update_round` must equal the legacy
/// sample-one/update-one interleave (the replay stream and the agent's
/// noise stream are independent).
#[test]
fn arena_round_equals_interleaved_sample_update() {
    let mut fill_rng = Pcg64::seed(3);
    let mut replay = ReplayBuffer::new(256, &[6], 2, Storage::F16);
    for _ in 0..200 {
        let o: Vec<f32> = (0..6).map(|_| fill_rng.normal_f32()).collect();
        let no: Vec<f32> = (0..6).map(|_| fill_rng.normal_f32()).collect();
        let act: Vec<f32> = (0..2).map(|_| fill_rng.uniform_in(-1.0, 1.0)).collect();
        replay.push(&o, &act, fill_rng.uniform_f32(), &no, false);
    }
    let mut legacy = build_states(Methods::ours(), Precision::fp16());
    let mut round = build_states(Methods::ours(), Precision::fp16());
    let mut r1 = Pcg64::seed_stream(9, 7);
    let mut r2 = Pcg64::seed_stream(9, 7);
    let mut arena = RoundArena::default();
    for _ in 0..6 {
        // legacy: sample → update, one at a time
        for _ in 0..4 {
            let batch = replay.sample(16, &mut r1);
            legacy.update(&batch);
        }
        // arena: sample the whole round, then update the round
        replay.sample_round_into(4, 16, None, &mut r2, &mut arena);
        round.update_round(arena.batches());
    }
    assert_agents_bitwise_equal(&mut legacy, &mut round, "arena round");
}

/// Pixel agents: fusion must engage (groups of target_update_freq) and
/// still match, including across round boundaries that move the group
/// phase.
#[test]
fn pixel_fusion_alignment_shifts_with_update_counter() {
    let (mut a, mut b) = (
        build_pixels(Methods::ours(), Precision::fp16()),
        build_pixels(Methods::ours(), Precision::fp16()),
    );
    let mut rng = Pcg64::seed(77);
    // odd-sized rounds so fused groups land on every phase of the
    // target_update_freq=2 cycle
    for round_len in [3usize, 2, 5, 1, 4] {
        let batches: Vec<Batch> = (0..round_len).map(|_| pixels_batch(2, &mut rng)).collect();
        for bt in &batches {
            a.update(bt);
        }
        b.update_round(&batches);
    }
    assert_agents_bitwise_equal(&mut a, &mut b, "pixel fusion phases");
}
