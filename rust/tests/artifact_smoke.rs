//! Integration: load the AOT artifacts, run train steps and policy
//! inference for every variant, and check the paper's headline numerics
//! claims across engines (fp16_ours stays finite; fp32 and fp16_ours
//! agree closely; fp16_naive degrades or dies).
//!
//! Requires the AOT artifacts (skips cleanly when absent so `cargo test`
//! works on a fresh checkout).

use lprl::rngs::Pcg64;
use lprl::runtime::TrainSession;

fn artifacts_dir() -> Option<std::path::PathBuf> {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    dir.join("manifest.txt").exists().then_some(dir)
}

/// Open a session, or skip (None) when the PJRT runtime itself is
/// unavailable — e.g. artifacts were generated but this is the offline
/// build with the stubbed `xla` bindings.
fn open_session(dir: &std::path::Path, variant: &str) -> Option<TrainSession> {
    match TrainSession::new(dir, variant) {
        Ok(s) => Some(s),
        Err(e) => {
            eprintln!("skipping: PJRT runtime unavailable: {e:#}");
            None
        }
    }
}

struct FakeBatch {
    obs: Vec<f32>,
    act: Vec<f32>,
    rew: Vec<f32>,
    next_obs: Vec<f32>,
    not_done: Vec<f32>,
    eps_next: Vec<f32>,
    eps_cur: Vec<f32>,
}

fn fake_batch(b: usize, o: usize, a: usize, rng: &mut Pcg64) -> FakeBatch {
    fn v(rng: &mut Pcg64, n: usize, s: f32) -> Vec<f32> {
        (0..n).map(|_| rng.normal_f32() * s).collect()
    }
    FakeBatch {
        obs: v(rng, b * o, 1.0),
        act: v(rng, b * a, 0.5).iter().map(|x| x.clamp(-1.0, 1.0)).collect(),
        rew: (0..b).map(|_| rng.uniform_f32()).collect(),
        next_obs: v(rng, b * o, 1.0),
        not_done: vec![1.0; b],
        eps_next: v(rng, b * a, 1.0),
        eps_cur: v(rng, b * a, 1.0),
    }
}

fn run_steps(variant: &str, n: usize, seed: u64) -> Option<Vec<[f32; 4]>> {
    let dir = artifacts_dir()?;
    let mut sess = open_session(&dir, variant)?;
    let (o, a, b) = sess.dims();
    let mut rng = Pcg64::seed(seed);
    let mut out = Vec::new();
    for _ in 0..n {
        let fb = fake_batch(b, o, a, &mut rng);
        let m = sess
            .step(&fb.obs, &fb.act, &fb.rew, &fb.next_obs, &fb.not_done, &fb.eps_next, &fb.eps_cur)
            .expect("step");
        out.push(m);
    }
    Some(out)
}

#[test]
fn all_variants_step_and_act() {
    let Some(dir) = artifacts_dir() else {
        eprintln!("skipping: generate artifacts with `python python/compile/aot.py` first");
        return;
    };
    for variant in ["fp32", "fp16_ours", "fp16_naive"] {
        let Some(mut sess) = open_session(&dir, variant) else { return };
        let (o, a, b) = sess.dims();
        assert!(o > 0 && a > 0 && b > 0);
        let mut rng = Pcg64::seed(1);
        let fb = fake_batch(b, o, a, &mut rng);
        let m = sess
            .step(&fb.obs, &fb.act, &fb.rew, &fb.next_obs, &fb.not_done, &fb.eps_next, &fb.eps_cur)
            .expect("step");
        // fp32 and ours must be finite on step one; naive may already NaN
        if variant != "fp16_naive" {
            assert!(m.iter().all(|x| x.is_finite()), "{variant}: {m:?}");
        }
        let action = sess.act(&vec![0.1; o], &vec![0.3; a]).expect("act");
        assert_eq!(action.len(), a);
        if variant != "fp16_naive" {
            assert!(action.iter().all(|x| x.is_finite() && x.abs() <= 1.0), "{variant}: {action:?}");
        }
    }
}

#[test]
fn fp16_ours_tracks_fp32_metrics() {
    if artifacts_dir().is_none() {
        return;
    }
    let (Some(m32), Some(m16)) = (run_steps("fp32", 10, 42), run_steps("fp16_ours", 10, 42))
    else {
        return;
    };
    for (a, b) in m32.iter().zip(&m16) {
        assert!(b.iter().all(|x| x.is_finite()), "fp16_ours must stay finite: {b:?}");
        // critic loss within a loose factor (identical batches, same seed)
        let (l32, l16) = (a[0].max(1e-4), b[0].max(1e-4));
        let ratio = (l32 / l16).max(l16 / l32);
        assert!(ratio < 3.0, "losses diverged: {l32} vs {l16}");
    }
}

#[test]
fn fp16_ours_state_stays_finite_over_many_steps() {
    if artifacts_dir().is_none() {
        return;
    }
    let Some(metrics) = run_steps("fp16_ours", 30, 7) else { return };
    let last = metrics.last().unwrap();
    assert!(last.iter().all(|x| x.is_finite()), "{last:?}");
}

#[test]
fn state_leaf_access() {
    let Some(dir) = artifacts_dir() else { return };
    let Some(sess) = open_session(&dir, "fp32") else { return };
    let t = sess.state_leaf("state.t").expect("t leaf");
    assert_eq!(t, vec![0.0]);
    let la = sess.state_leaf("state.params.log_alpha").expect("log_alpha");
    assert!((la[0] - 0.1f32.ln()).abs() < 1e-5);
}
