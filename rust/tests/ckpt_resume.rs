//! Crash-safety integration matrix: checkpoint + injected kill + resume
//! must be bitwise-indistinguishable from an uninterrupted run across
//! presets (states + pixels), weight storage (f32 + f16), and both
//! interleave contracts (strict + async); torn checkpoint files must be
//! caught by the checksum and recovery must fall back to the previous
//! generation.

use std::path::PathBuf;

use lprl::ckpt::CkptStore;
use lprl::config::RunConfig;
use lprl::coordinator::{train, TrainOutcome};

fn states_cfg(preset: &str, storage: &str, sync_mode: &str) -> RunConfig {
    RunConfig {
        task: "pendulum_swingup".into(),
        preset: preset.into(),
        storage: storage.into(),
        sync_mode: sync_mode.into(),
        steps: 120,
        seed_steps: 40,
        batch: 16,
        hidden: 24,
        eval_every: 60,
        eval_episodes: 1,
        num_envs: if sync_mode == "async" { 4 } else { 1 },
        ..Default::default()
    }
}

fn pixels_cfg(preset: &str, storage: &str, sync_mode: &str) -> RunConfig {
    RunConfig {
        pixels: true,
        image_size: 17,
        filters: 4,
        feature_dim: 8,
        hidden: 16,
        steps: 40,
        seed_steps: 20,
        batch: 4,
        eval_every: 40,
        num_envs: if sync_mode == "async" { 3 } else { 1 },
        ..states_cfg(preset, storage, sync_mode)
    }
}

/// Fresh scratch dir for a run's checkpoint store.
fn scratch(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("lprl_ckpt_it_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    d
}

/// Bit pattern of the final policy's deterministic action on a fixed
/// probe observation — exact equality means the params match bitwise.
fn probe(out: &TrainOutcome) -> Vec<u32> {
    let p = out.policy.as_ref().expect("train keeps the final policy");
    let obs: Vec<f32> = (0..p.obs_len()).map(|i| ((i as f32) * 0.37).sin()).collect();
    let t = p.obs_tensor(&obs, 1);
    p.act_batch(&t, lprl::sac::ActMode::Deterministic).data.iter().map(|v| v.to_bits()).collect()
}

/// The resume-equivalence contract: run uninterrupted; run again with
/// checkpoints + an injected kill; resume from the surviving store; the
/// resumed run must match the uninterrupted one bitwise. Returns the
/// scratch dir (still populated) for follow-up assertions.
fn assert_resume_equivalent(
    base_cfg: &RunConfig,
    tag: &str,
    checkpoint_every: usize,
    faults: &str,
) -> PathBuf {
    let base = train(base_cfg);
    assert!(!base.crashed, "{tag}: baseline must not crash");

    let dir = scratch(tag);
    let mut kill_cfg = base_cfg.clone();
    kill_cfg.out_dir = dir.to_string_lossy().into_owned();
    kill_cfg.checkpoint_every = checkpoint_every;
    kill_cfg.faults = faults.into();
    let killed = train(&kill_cfg);
    assert!(killed.killed, "{tag}: {faults} must stop the run early");
    assert!(!killed.crashed, "{tag}: a kill is not a crash");

    let mut res_cfg = base_cfg.clone();
    res_cfg.resume_from = dir.join("ckpt").to_string_lossy().into_owned();
    let resumed = train(&res_cfg);
    assert!(!resumed.killed && !resumed.crashed, "{tag}: resumed run must finish");
    assert_eq!(
        resumed.eval_curve.points, base.eval_curve.points,
        "{tag}: resumed eval curve must match the uninterrupted run"
    );
    assert_eq!(
        resumed.replay_fingerprint, base.replay_fingerprint,
        "{tag}: resumed replay contents must match"
    );
    assert_eq!(resumed.updates, base.updates, "{tag}: update counters must match");
    assert_eq!(resumed.skipped_steps, base.skipped_steps, "{tag}: skip counters must match");
    assert_eq!(probe(&resumed), probe(&base), "{tag}: final params must match bitwise");
    dir
}

// -- the acceptance matrix: preset family × storage × sync_mode ---------

#[test]
fn states_f32_strict_resume_is_bitwise_identical() {
    let dir = assert_resume_equivalent(
        &states_cfg("fp32", "f32", "strict"),
        "st_f32_strict",
        25,
        "kill@80:round",
    );
    let _ = std::fs::remove_dir_all(dir);
}

#[test]
fn states_f16_strict_resume_is_bitwise_identical() {
    let dir = assert_resume_equivalent(
        &states_cfg("fp16_ours", "f16", "strict"),
        "st_f16_strict",
        25,
        "kill@80:round",
    );
    let _ = std::fs::remove_dir_all(dir);
}

#[test]
fn states_f32_async_resume_is_bitwise_identical() {
    let dir = assert_resume_equivalent(
        &states_cfg("fp32", "f32", "async"),
        "st_f32_async",
        25,
        "kill@80:round",
    );
    let _ = std::fs::remove_dir_all(dir);
}

#[test]
fn states_f16_async_resume_is_bitwise_identical() {
    let dir = assert_resume_equivalent(
        &states_cfg("fp16_ours", "f16", "async"),
        "st_f16_async",
        25,
        "kill@80:round",
    );
    let _ = std::fs::remove_dir_all(dir);
}

#[test]
fn pixels_f32_strict_resume_is_bitwise_identical() {
    let dir = assert_resume_equivalent(
        &pixels_cfg("fp32", "f32", "strict"),
        "px_f32_strict",
        15,
        "kill@30:ckpt",
    );
    let _ = std::fs::remove_dir_all(dir);
}

#[test]
fn pixels_f16_strict_resume_is_bitwise_identical() {
    let dir = assert_resume_equivalent(
        &pixels_cfg("fp16_ours", "f16", "strict"),
        "px_f16_strict",
        15,
        "kill@30:ckpt",
    );
    let _ = std::fs::remove_dir_all(dir);
}

#[test]
fn pixels_f32_async_resume_is_bitwise_identical() {
    let dir = assert_resume_equivalent(
        &pixels_cfg("fp32", "f32", "async"),
        "px_f32_async",
        15,
        "kill@30:ckpt",
    );
    let _ = std::fs::remove_dir_all(dir);
}

#[test]
fn pixels_f16_async_resume_is_bitwise_identical() {
    let dir = assert_resume_equivalent(
        &pixels_cfg("fp16_ours", "f16", "async"),
        "px_f16_async",
        15,
        "kill@30:ckpt",
    );
    let _ = std::fs::remove_dir_all(dir);
}

// -- torn-write recovery: checksum detection + generation fallback ------

fn assert_torn_falls_back(tag: &str, torn_mode: &str) {
    let base_cfg = states_cfg("fp32", "f32", "strict");
    let base = train(&base_cfg);

    let dir = scratch(tag);
    let mut kill_cfg = base_cfg.clone();
    kill_cfg.out_dir = dir.to_string_lossy().into_owned();
    kill_cfg.checkpoint_every = 25;
    // damage the generation written at step 75, then die at step 80
    kill_cfg.faults = format!("torn@75:{torn_mode}, kill@80:round");
    let killed = train(&kill_cfg);
    assert!(killed.killed && !killed.crashed);

    let store = CkptStore::open(dir.join("ckpt"), base_cfg.ckpt_keep).unwrap();
    let gens = store.generations().unwrap();
    let steps: Vec<u64> = gens.iter().map(|&(s, _)| s).collect();
    assert_eq!(steps, vec![25, 50, 75], "{tag}: retention keeps the last 3 generations");
    // the checksum/format validator must reject the damaged newest file...
    let newest = &gens.last().unwrap().1;
    assert!(
        CkptStore::read_file(newest).is_err(),
        "{tag}: the torn generation must fail validation"
    );
    // ...and load_latest must transparently fall back one generation
    let (step, _) = store.load_latest().unwrap().expect("an intact generation survives");
    assert_eq!(step, 50, "{tag}: recovery falls back to the previous generation");
    assert!(!store.has_stale_temps().unwrap(), "{tag}: no stale temp files left behind");
    drop(store);

    // resuming from the damaged store silently uses generation 50 and —
    // by the determinism contract — still matches the baseline bitwise
    let mut res_cfg = base_cfg.clone();
    res_cfg.resume_from = dir.join("ckpt").to_string_lossy().into_owned();
    let resumed = train(&res_cfg);
    assert!(!resumed.killed && !resumed.crashed);
    assert_eq!(resumed.eval_curve.points, base.eval_curve.points);
    assert_eq!(resumed.replay_fingerprint, base.replay_fingerprint);
    assert_eq!(probe(&resumed), probe(&base));
    let _ = std::fs::remove_dir_all(dir);
}

#[test]
fn corrupt_checkpoint_is_detected_and_recovery_falls_back() {
    assert_torn_falls_back("torn_corrupt", "corrupt");
}

#[test]
fn truncated_checkpoint_is_detected_and_recovery_falls_back() {
    assert_torn_falls_back("torn_truncate", "truncate");
}

#[test]
fn stale_temp_files_are_cleaned_on_open() {
    // a temp file left by a crash mid-write must be swept the next time
    // the store opens (the resume path), never mistaken for a generation
    let base_cfg = states_cfg("fp32", "f32", "strict");
    let base = train(&base_cfg);

    let dir = scratch("stale_tmp");
    let mut kill_cfg = base_cfg.clone();
    kill_cfg.out_dir = dir.to_string_lossy().into_owned();
    kill_cfg.checkpoint_every = 25;
    kill_cfg.faults = "kill@80:round".into();
    let killed = train(&kill_cfg);
    assert!(killed.killed);

    let ckpt_dir = dir.join("ckpt");
    std::fs::write(ckpt_dir.join("ckpt-00000000000000000099.lprl.tmp"), b"torn write").unwrap();
    let store = CkptStore::open(&ckpt_dir, base_cfg.ckpt_keep).unwrap();
    assert!(!store.has_stale_temps().unwrap(), "open must sweep stale temps");
    drop(store);

    std::fs::write(ckpt_dir.join("ckpt-00000000000000000099.lprl.tmp"), b"torn write").unwrap();
    let mut res_cfg = base_cfg.clone();
    res_cfg.resume_from = ckpt_dir.to_string_lossy().into_owned();
    let resumed = train(&res_cfg);
    assert!(!resumed.crashed);
    assert_eq!(resumed.eval_curve.points, base.eval_curve.points);
    assert!(
        !CkptStore::open(&ckpt_dir, base_cfg.ckpt_keep).unwrap().has_stale_temps().unwrap(),
        "the resume path must have swept the stale temp"
    );
    let _ = std::fs::remove_dir_all(dir);
}
