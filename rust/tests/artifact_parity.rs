//! Cross-engine parity: the native Rust engine and the AOT JAX artifact
//! must implement the *same* computation. We load the artifact's initial
//! actor weights into the native `Mlp` and check that both engines
//! produce the same actions for the same observations and noise.
//!
//! Skips cleanly if the AOT artifacts have not been generated.

use lprl::lowp::Precision;
use lprl::nn::{Mlp, Tensor};
use lprl::rngs::Pcg64;
use lprl::runtime::TrainSession;
use lprl::sac::TanhGaussian;

fn artifacts_dir() -> Option<std::path::PathBuf> {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    dir.join("manifest.txt").exists().then_some(dir)
}

/// Open a session, or skip (None) when the PJRT runtime itself is
/// unavailable (offline build with the stubbed `xla` bindings).
fn open_session(dir: &std::path::Path, variant: &str) -> Option<TrainSession> {
    match TrainSession::new(dir, variant) {
        Ok(s) => Some(s),
        Err(e) => {
            eprintln!("skipping: PJRT runtime unavailable: {e:#}");
            None
        }
    }
}

/// Build a native Mlp whose weights are the artifact's initial actor.
fn native_actor(sess: &TrainSession, o: usize, a: usize, hidden: usize) -> Mlp {
    let mut rng = Pcg64::seed(0);
    let mut mlp = Mlp::new("actor", &[o, hidden, hidden, 2 * a], &mut rng);
    for (i, layer) in mlp.layers.iter_mut().enumerate() {
        let w = sess.state_leaf(&format!("state.params.actor.l{i}.w")).unwrap();
        let b = sess.state_leaf(&format!("state.params.actor.l{i}.b")).unwrap();
        layer.w.w.copy_from_slice(&w);
        layer.b.w.copy_from_slice(&b);
    }
    mlp
}

#[test]
fn native_and_artifact_actions_agree_fp32() {
    let Some(dir) = artifacts_dir() else {
        eprintln!("skipping: generate artifacts with `python python/compile/aot.py`");
        return;
    };
    let Some(mut sess) = open_session(&dir, "fp32") else { return };
    let (o, a, _) = sess.dims();
    let hidden = sess.runtime.manifest.dim("hidden").unwrap();
    let actor = native_actor(&sess, o, a, hidden);

    let mut rng = Pcg64::seed(17);
    for trial in 0..20 {
        let obs: Vec<f32> = (0..o).map(|_| rng.normal_f32()).collect();
        let eps: Vec<f32> = (0..a).map(|_| rng.normal_f32()).collect();
        let art_action = sess.act(&obs, &eps).unwrap();
        let head = actor.forward(&Tensor::from_vec(&[1, o], obs.clone()), Precision::Fp32);
        let tg = TanhGaussian::forward(
            &head,
            &Tensor::from_vec(&[1, a], eps.clone()),
            Default::default(),
            Precision::Fp32,
        );
        for i in 0..a {
            let (x, y) = (art_action[i], tg.a.data[i]);
            assert!(
                (x - y).abs() < 2e-3,
                "trial {trial} dim {i}: artifact {x} vs native {y}"
            );
        }
    }
}

#[test]
fn native_and_artifact_actions_agree_fp16_ours() {
    let Some(dir) = artifacts_dir() else { return };
    let Some(mut sess) = open_session(&dir, "fp16_ours") else { return };
    let (o, a, _) = sess.dims();
    let hidden = sess.runtime.manifest.dim("hidden").unwrap();
    let actor = native_actor(&sess, o, a, hidden);
    let prec = Precision::fp16();

    let mut rng = Pcg64::seed(23);
    let mut max_err = 0.0f32;
    for _ in 0..20 {
        let obs: Vec<f32> = (0..o).map(|_| rng.normal_f32()).collect();
        let eps: Vec<f32> = (0..a).map(|_| rng.normal_f32()).collect();
        let art_action = sess.act(&obs, &eps).unwrap();
        let head = actor.forward(&Tensor::from_vec(&[1, o], obs.clone()), prec);
        let tg = TanhGaussian::forward(
            &head,
            &Tensor::from_vec(&[1, a], eps.clone()),
            Default::default(),
            prec,
        );
        for i in 0..a {
            max_err = max_err.max((art_action[i] - tg.a.data[i]).abs());
        }
    }
    // fp16 engines may differ by a few ulps through the MLP (XLA fuses,
    // the native engine rounds per tensor-op); actions live in [-1,1]
    assert!(max_err < 2e-2, "max action error {max_err}");
}

#[test]
fn artifact_weights_are_f16_representable_for_fp16_variants() {
    let Some(dir) = artifacts_dir() else { return };
    let Some(sess) = open_session(&dir, "fp16_ours") else { return };
    let w = sess.state_leaf("state.params.actor.l0.w").unwrap();
    for &v in &w {
        assert!(lprl::lowp::FP16.is_representable(v), "{v}");
    }
}
