//! Integration tests for the train/inference API split:
//!
//! * `act` vs `act_batch(1)` vs `Policy::act_batch` bitwise parity,
//!   under every precision preset (the serve layer's correctness
//!   invariant);
//! * looped vs batched deterministic evaluation parity on every
//!   supported task;
//! * snapshot independence (training after `policy()` must not change
//!   the snapshot's outputs);
//! * K concurrent serve clients receive exactly the actions serial
//!   calls produce.

use lprl::config::{parse_preset, RunConfig};
use lprl::coordinator::{evaluate_policy, evaluate_policy_batched};
use lprl::envs::{make_env, SUPPORTED_TASKS};
use lprl::nn::Tensor;
use lprl::rngs::Pcg64;
use lprl::sac::{ActMode, Batch, SacAgent, SacConfig};
use lprl::serve::{NativeBackend, PolicyServer, ServeConfig};
use std::sync::Arc;

fn toy_agent(obs_dim: usize, act_dim: usize, preset: &str, seed: u64) -> SacAgent {
    let (prec, methods) = parse_preset(preset).unwrap_or_else(|| panic!("preset {preset}"));
    SacAgent::new(SacConfig::states(obs_dim, act_dim, 32), methods, prec, seed)
}

fn toy_batch(b: usize, obs_dim: usize, act_dim: usize, rng: &mut Pcg64) -> Batch {
    let mut obs = Tensor::zeros(&[b, obs_dim]);
    rng.normal_fill(&mut obs.data);
    let mut next_obs = Tensor::zeros(&[b, obs_dim]);
    rng.normal_fill(&mut next_obs.data);
    let mut act = Tensor::zeros(&[b, act_dim]);
    for v in act.data.iter_mut() {
        *v = rng.uniform_in(-1.0, 1.0);
    }
    Batch {
        obs,
        act,
        rew: (0..b).map(|_| rng.uniform_f32()).collect(),
        next_obs,
        not_done: vec![1.0; b],
    }
}

fn assert_bitwise(a: &[f32], b: &[f32], tag: &str) {
    assert_eq!(a.len(), b.len(), "{tag}: length");
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "{tag}[{i}]: {x} vs {y}");
    }
}

/// Acceptance invariant: batch-32 `act_batch` rows are bitwise equal to
/// per-observation `act`, for every precision preset, and the immutable
/// `Policy` snapshot agrees with the live agent.
#[test]
fn act_batch_rows_match_single_act_under_every_preset() {
    let presets = [
        "fp32",
        "fp16_naive",
        "fp16_ours",
        "coerc",
        "loss_scale",
        "mixed",
        "amp",
        "bf16_ours",
        "e5m7_ours",
    ];
    for preset in presets {
        let (od, ad, b) = (6, 3, 32);
        let mut agent = toy_agent(od, ad, preset, 5);
        let mut obs = Tensor::zeros(&[b, od]);
        Pcg64::seed(11).normal_fill(&mut obs.data);
        let batched = agent.act_batch(&obs, false).expect("finite actions");
        for r in 0..b {
            let single = agent.act(obs.row(r), false).expect("finite action");
            assert_bitwise(&single, batched.row(r), &format!("{preset} row {r}"));
        }
        let policy = agent.policy();
        let snap = policy.act_batch(&obs, ActMode::Deterministic);
        assert_bitwise(&snap.data, &batched.data, preset);
    }
}

/// The stochastic path consumes the agent RNG identically whether it
/// goes through `act` or `act_batch(1)` (act is act_batch with batch 1).
#[test]
fn stochastic_act_is_act_batch_of_one() {
    let mut a1 = toy_agent(5, 2, "fp16_ours", 9);
    let mut a2 = toy_agent(5, 2, "fp16_ours", 9);
    let mut rng = Pcg64::seed(3);
    for step in 0..10 {
        let obs: Vec<f32> = (0..5).map(|_| rng.normal_f32()).collect();
        let x = a1.act(&obs, true).unwrap();
        let t = Tensor::from_vec(&[1, 5], obs.clone());
        let y = a2.act_batch(&t, true).unwrap();
        assert_bitwise(&x, &y.data, &format!("step {step}"));
    }
}

/// Updating the agent after `policy()` must not change the snapshot.
#[test]
fn policy_snapshot_is_independent_of_later_updates() {
    let mut rng = Pcg64::seed(4);
    let mut agent = toy_agent(6, 2, "fp32", 1);
    let mut obs = Tensor::zeros(&[4, 6]);
    rng.normal_fill(&mut obs.data);
    let policy = agent.policy();
    let before = policy.act_batch(&obs, ActMode::Deterministic);
    for _ in 0..5 {
        let b = toy_batch(16, 6, 2, &mut rng);
        agent.update(&b);
    }
    let after = policy.act_batch(&obs, ActMode::Deterministic);
    assert_bitwise(&before.data, &after.data, "snapshot must be frozen");
    // ... while the live agent has moved on
    let live = agent.act_batch(&obs, false).unwrap();
    assert_ne!(live.data, before.data, "agent must keep training");
    // and a fresh snapshot tracks the live agent again
    let fresh = agent.policy().act_batch(&obs, ActMode::Deterministic);
    assert_bitwise(&fresh.data, &live.data, "fresh snapshot");
}

/// Batched lockstep evaluation is bitwise identical to one-episode-at-
/// a-time evaluation on every supported task.
#[test]
fn batched_eval_matches_looped_eval_on_every_task() {
    for task in SUPPORTED_TASKS {
        let cfg = RunConfig {
            task: task.to_string(),
            preset: "fp16_ours".into(),
            hidden: 24,
            ..Default::default()
        };
        let env = make_env(task).unwrap();
        let (prec, methods) = cfg.preset().unwrap();
        let agent = SacAgent::new(
            SacConfig::states(env.obs_dim(), env.act_dim(), cfg.hidden),
            methods,
            prec,
            3,
        );
        let policy = agent.policy();
        let looped = evaluate_policy(&policy, &cfg, 2, 0x5EED).unwrap();
        let batched = evaluate_policy_batched(&policy, &cfg, 2, 0x5EED).unwrap();
        assert_eq!(
            looped.to_bits(),
            batched.to_bits(),
            "{task}: looped {looped} vs batched {batched}"
        );
    }
}

/// The same parity guarantees hold on the pixel path, where the policy
/// snapshot additionally carries the conv encoder with its weight
/// standardization baked into the frozen head weights: the snapshot
/// matches the live agent bitwise, and batched lockstep eval matches
/// looped eval bitwise.
#[test]
fn pixel_policy_snapshot_and_batched_eval_parity() {
    let cfg = RunConfig {
        task: "pendulum_swingup".into(),
        preset: "fp16_ours".into(),
        pixels: true,
        image_size: 17,
        filters: 4,
        frame_stack: 3,
        feature_dim: 8,
        hidden: 16,
        ..Default::default()
    };
    let (prec, methods) = cfg.preset().unwrap();
    let env = make_env(&cfg.task).unwrap();
    let sac_cfg = SacConfig::pixels(cfg.feature_dim, env.act_dim(), cfg.hidden);
    let mut agent = SacAgent::new_pixels(
        sac_cfg,
        methods,
        prec,
        3,
        cfg.frame_stack * 3,
        cfg.image_size,
        cfg.filters,
    );

    // snapshot vs live agent, batch vs single, all bitwise
    let (c, h) = (cfg.frame_stack * 3, cfg.image_size);
    let mut img = Tensor::zeros(&[2, c, h, h]);
    let mut rng = Pcg64::seed(6);
    for v in img.data.iter_mut() {
        *v = rng.uniform_f32();
    }
    let live = agent.act_batch(&img, false).unwrap();
    let policy = agent.policy();
    assert_eq!(policy.obs_len(), c * h * h);
    let snap = policy.act_batch(&img, ActMode::Deterministic);
    assert_bitwise(&live.data, &snap.data, "pixel snapshot vs live");
    let img_len = c * h * h;
    for r in 0..2 {
        // act takes one flattened [C, H, W] image
        let single = agent.act(&img.data[r * img_len..(r + 1) * img_len], false).unwrap();
        assert_bitwise(&single, snap.row(r), &format!("pixel row {r}"));
    }

    // looped vs batched deterministic eval through the pixel adapter
    let looped = evaluate_policy(&policy, &cfg, 2, 0x5EED).unwrap();
    let batched = evaluate_policy_batched(&policy, &cfg, 2, 0x5EED).unwrap();
    assert_eq!(looped.to_bits(), batched.to_bits(), "{looped} vs {batched}");
}

/// K concurrent clients through the micro-batching server receive
/// exactly the actions that serial `act_batch(·, 1)` calls produce.
#[test]
fn concurrent_serve_clients_match_serial_calls() {
    let agent = toy_agent(8, 3, "fp16_ours", 2);
    let policy = agent.policy();
    let k = 16usize;
    let mut rng = Pcg64::seed(5);
    let obs: Vec<Vec<f32>> = (0..k)
        .map(|_| (0..8).map(|_| rng.normal_f32()).collect())
        .collect();
    // serial reference, batch-1 each
    let serial: Vec<Vec<f32>> = obs
        .iter()
        .map(|o| {
            policy
                .act_batch(&Tensor::from_vec(&[1, 8], o.clone()), ActMode::Deterministic)
                .data
        })
        .collect();

    let server = PolicyServer::start(
        Arc::new(NativeBackend::new(policy.clone())),
        ServeConfig { max_batch: 4, flush_us: 5_000, queue_cap: 64, ..ServeConfig::default() },
    );
    std::thread::scope(|s| {
        let mut handles = Vec::new();
        for o in &obs {
            let client = server.client();
            handles.push(s.spawn(move || client.act(o).unwrap()));
        }
        for (i, h) in handles.into_iter().enumerate() {
            let got = h.join().unwrap();
            assert_bitwise(&got, &serial[i], &format!("client {i}"));
        }
    });
    let stats = server.shutdown();
    assert_eq!(stats.requests, k as u64);
    assert_eq!(stats.errors, 0);
}
