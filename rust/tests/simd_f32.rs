//! f32 SIMD compute-plane contracts (INVARIANTS.md §3b):
//!
//! * the vectorized f32 GEMM kernels (`gemm`/`gemm_nt`/`gemm_tn` with
//!   the fused bias+quantize epilogue) are **bitwise equal** to the
//!   scalar oracle at every runtime feature level, shape, and epilogue
//!   precision;
//! * the vectorized slice RNE quantizer is bitwise equal to the scalar
//!   integer bit path for every simulated format, including the special
//!   values (±0, ±inf, NaN payloads, subnormals, overflow boundary);
//! * the SIMD half pack/unpack routines the replay ring and weight
//!   stores route through are bitwise equal to the scalar encode/decode;
//! * a full trainer run is bitwise identical with `LPRL_SIMD=0` forced
//!   (CI also runs this whole binary under both legs).

use lprl::lowp::{e5m, Precision};
use lprl::lowp::HalfFormat;
use lprl::nn::gemm::{
    gemm_bias_q, gemm_bias_q_at, gemm_nt_bias_q, gemm_nt_bias_q_at, gemm_tn_bias_q,
    gemm_tn_bias_q_at,
};
use lprl::nn::simd;
use lprl::rngs::Pcg64;

/// Learner-representative shapes plus the edge/remainder cases around
/// the 4x16 register tile.
const SHAPES: &[(usize, usize, usize)] =
    &[(1, 1, 1), (2, 3, 5), (4, 16, 16), (5, 17, 33), (16, 64, 48), (33, 40, 19), (64, 96, 128)];

fn fill(rng: &mut Pcg64, len: usize) -> Vec<f32> {
    (0..len).map(|_| rng.normal_f32()).collect()
}

fn precisions() -> Vec<Precision> {
    vec![Precision::Fp32, Precision::fp16(), Precision::sim(lprl::lowp::BF16), Precision::sim(e5m(7))]
}

fn assert_bitwise(got: &[f32], want: &[f32], what: &str) {
    for (i, (x, y)) in got.iter().zip(want).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "{what} elem {i}: {x:e} vs {y:e}");
    }
}

#[test]
fn f32_gemm_matches_scalar_oracle_across_shapes_and_precisions() {
    let detected = simd::detect();
    println!("parity gate: {}", simd::feature_summary());
    let mut rng = Pcg64::seed(61);
    for &(m, k, n) in SHAPES {
        let a = fill(&mut rng, m * k);
        let b = fill(&mut rng, k * n);
        let bias = fill(&mut rng, n);
        for prec in precisions() {
            for bias_opt in [Some(bias.as_slice()), None] {
                let mut oracle = vec![0.0f32; m * n];
                gemm_bias_q_at(simd::Level::Scalar, &a, &b, &mut oracle, m, k, n, bias_opt, prec);
                let mut fast = vec![0.0f32; m * n];
                gemm_bias_q_at(detected, &a, &b, &mut fast, m, k, n, bias_opt, prec);
                assert_bitwise(&fast, &oracle, &format!("{} gemm {m}x{k}x{n}", detected.name()));
                // the public auto-dispatch entry lands on the same bits
                let mut auto = vec![0.0f32; m * n];
                gemm_bias_q(&a, &b, &mut auto, m, k, n, bias_opt, prec);
                assert_bitwise(&auto, &oracle, &format!("auto gemm {m}x{k}x{n}"));
            }
        }
    }
}

#[test]
fn f32_gemm_nt_matches_scalar_oracle_across_shapes_and_precisions() {
    let detected = simd::detect();
    let mut rng = Pcg64::seed(67);
    for &(m, k, n) in SHAPES {
        let a = fill(&mut rng, m * k);
        let bt = fill(&mut rng, n * k);
        let bias = fill(&mut rng, n);
        for prec in precisions() {
            let mut oracle = vec![0.0f32; m * n];
            gemm_nt_bias_q_at(
                simd::Level::Scalar,
                &a,
                &bt,
                &mut oracle,
                m,
                k,
                n,
                Some(&bias),
                prec,
            );
            let mut fast = vec![0.0f32; m * n];
            gemm_nt_bias_q_at(detected, &a, &bt, &mut fast, m, k, n, Some(&bias), prec);
            assert_bitwise(&fast, &oracle, &format!("{} gemm_nt {m}x{k}x{n}", detected.name()));
            let mut auto = vec![0.0f32; m * n];
            gemm_nt_bias_q(&a, &bt, &mut auto, m, k, n, Some(&bias), prec);
            assert_bitwise(&auto, &oracle, &format!("auto gemm_nt {m}x{k}x{n}"));
        }
    }
}

#[test]
fn f32_gemm_tn_matches_scalar_oracle_across_shapes_and_precisions() {
    let detected = simd::detect();
    let mut rng = Pcg64::seed(71);
    for &(m, k, n) in SHAPES {
        let at = fill(&mut rng, k * m);
        let b = fill(&mut rng, k * n);
        let bias = fill(&mut rng, n);
        for prec in precisions() {
            let mut oracle = vec![0.0f32; m * n];
            gemm_tn_bias_q_at(
                simd::Level::Scalar,
                &at,
                &b,
                &mut oracle,
                m,
                k,
                n,
                Some(&bias),
                prec,
            );
            let mut fast = vec![0.0f32; m * n];
            gemm_tn_bias_q_at(detected, &at, &b, &mut fast, m, k, n, Some(&bias), prec);
            assert_bitwise(&fast, &oracle, &format!("{} gemm_tn {m}x{k}x{n}", detected.name()));
            let mut auto = vec![0.0f32; m * n];
            gemm_tn_bias_q(&at, &b, &mut auto, m, k, n, Some(&bias), prec);
            assert_bitwise(&auto, &oracle, &format!("auto gemm_tn {m}x{k}x{n}"));
        }
    }
}

/// Every quantizer branch: ties, subnormals (f32's and the target's),
/// overflow boundary, signed zero, infinities, NaN payloads, plus a
/// dense random sweep of raw bit patterns.
fn quantizer_inputs(rng: &mut Pcg64) -> Vec<f32> {
    let mut xs = vec![
        0.0,
        -0.0,
        1.0,
        -1.0,
        65504.0,
        65519.0,
        65520.0,
        1e6,
        -1e6,
        1e-9,
        6.1035156e-5,
        5.9604645e-8,
        2.9802322e-8,
        1.0 + 4.8828125e-4,
        f32::INFINITY,
        f32::NEG_INFINITY,
        f32::MAX,
        f32::MIN_POSITIVE,
        f32::from_bits(1),
        1e-40,
        -1e-40,
        f32::from_bits(0x7f80_0001), // signaling-NaN payload
        f32::from_bits(0xffc0_1234), // quiet-NaN payload
    ];
    xs.extend((0..20_000).map(|_| f32::from_bits(rng.next_u32())));
    xs
}

#[test]
fn slice_quantizer_matches_scalar_oracle_across_formats() {
    let detected = simd::detect();
    let mut rng = Pcg64::seed(73);
    let base = quantizer_inputs(&mut rng);
    let formats: &[(u8, u8)] =
        &[(5, 10), (8, 7), (5, 7), (5, 5), (4, 3), (8, 10), (2, 1), (5, 1), (8, 22), (5, 0)];
    for &(e, m) in formats {
        let mut oracle = base.clone();
        simd::quantize_slice_rne_at(simd::Level::Scalar, e, m, &mut oracle);
        let mut fast = base.clone();
        simd::quantize_slice_rne_at(detected, e, m, &mut fast);
        assert_bitwise(&fast, &oracle, &format!("{} quantize e{e}m{m}", detected.name()));
        // the hooked dispatch entry (Precision::q_slice's bit path)
        let mut auto = base.clone();
        simd::quantize_slice_rne(e, m, &mut auto);
        assert_bitwise(&auto, &oracle, &format!("auto quantize e{e}m{m}"));
    }
}

#[test]
fn half_pack_unpack_match_scalar_oracle() {
    let detected = simd::detect();
    let mut rng = Pcg64::seed(79);
    let xs = quantizer_inputs(&mut rng);
    for fmt in [HalfFormat::F16, HalfFormat::Bf16] {
        let mut oracle = vec![0u16; xs.len()];
        simd::pack_half_slice_at(simd::Level::Scalar, fmt, &xs, &mut oracle);
        let mut fast = vec![0u16; xs.len()];
        simd::pack_half_slice_at(detected, fmt, &xs, &mut fast);
        assert_eq!(fast, oracle, "{} {} pack", detected.name(), fmt.name());

        // unpack every stored word the pack produced, plus every 16-bit
        // pattern in a dense stripe, at both levels
        let words: Vec<u16> = oracle.iter().copied().chain(0..=u16::MAX).collect();
        let mut want = vec![0.0f32; words.len()];
        simd::unpack_half_slice_at(simd::Level::Scalar, fmt, &words, &mut want);
        let mut got = vec![0.0f32; words.len()];
        simd::unpack_half_slice_at(detected, fmt, &words, &mut got);
        assert_bitwise(&got, &want, &format!("{} {} unpack", detected.name(), fmt.name()));
    }
}

/// End-to-end leg of the parity gate: the same short training run, once
/// with the environment as-is (auto dispatch) and once with
/// `LPRL_SIMD=0` forcing the scalar tier, must produce bitwise-identical
/// eval curves. Levels are process-global (detected once), so each leg
/// runs in its own child process of the `lprl` binary and the written
/// CSV (shortest-roundtrip float formatting — byte equality is bitwise
/// equality) plus the printed curve are compared.
#[test]
fn trainer_run_is_bitwise_identical_with_simd_forced_off() {
    let exe = env!("CARGO_BIN_EXE_lprl");
    let out_root = std::env::temp_dir().join(format!("lprl-simd-e2e-{}", std::process::id()));
    let run = |leg: &str, force_scalar: bool| -> (Vec<String>, String) {
        let out_dir = out_root.join(leg);
        let mut cmd = std::process::Command::new(exe);
        cmd.args([
            "train",
            "task=cartpole_swingup",
            "preset=fp16_ours",
            "steps=120",
            "seed_steps=40",
            "batch=16",
            "hidden=24",
            "eval_every=60",
            "eval_episodes=1",
            "replay_storage=u8",
        ]);
        cmd.arg(format!("out_dir={}", out_dir.display()));
        if force_scalar {
            cmd.env("LPRL_SIMD", "0");
        } else {
            cmd.env_remove("LPRL_SIMD");
        }
        let out = cmd.output().expect("failed to launch lprl train");
        assert!(
            out.status.success(),
            "train leg {leg} failed:\n{}",
            String::from_utf8_lossy(&out.stderr)
        );
        let curve: Vec<String> = String::from_utf8(out.stdout)
            .unwrap()
            .lines()
            .filter(|l| l.starts_with("  env_step") || l.starts_with("task="))
            .map(str::to_string)
            .collect();
        let csv = out_dir.join("train").join("cartpole_swingup_fp16_ours_s0.csv");
        let csv = std::fs::read_to_string(&csv)
            .unwrap_or_else(|e| panic!("leg {leg}: missing {}: {e}", csv.display()));
        (curve, csv)
    };
    let (auto_curve, auto_csv) = run("auto", false);
    let (scalar_curve, scalar_csv) = run("scalar", true);
    assert!(!auto_curve.is_empty(), "train printed no eval curve");
    assert_eq!(auto_curve, scalar_curve, "LPRL_SIMD=0 must not change the eval curve");
    assert_eq!(auto_csv, scalar_csv, "LPRL_SIMD=0 must not change a single written byte");
    let _ = std::fs::remove_dir_all(&out_root);
}
