//! Relaxed-determinism contract of the async collector/learner pipeline
//! (`sync_mode = "async"`), and its backpressure behavior.
//!
//! The contract under test (see `coordinator::pipeline`):
//! * async runs are **bitwise deterministic in the seed** — queue and
//!   thread timing must not leak into results (the snapshot protocol is
//!   deterministically lagged, the env streams are seed-owned);
//! * vs strict mode the **update count** and **eval step grid** are
//!   identical (the round schedule and step-budget accountant are
//!   shared), and the **seed-phase transition multiset** is bitwise
//!   identical (same per-env streams feed both collectors);
//! * a full transition queue (slow learner) and an empty one (slow
//!   collector) both block without losing progress, transitions, or
//!   updates.

use lprl::config::RunConfig;
use lprl::coordinator::{run_many, train, TrainOutcome};

fn base_cfg() -> RunConfig {
    RunConfig {
        task: "pendulum_swingup".into(),
        preset: "fp16_ours".into(),
        steps: 120,
        seed_steps: 40,
        batch: 16,
        hidden: 24,
        eval_every: 60,
        eval_episodes: 1,
        num_envs: 4,
        sync_mode: "async".into(),
        ..Default::default()
    }
}

fn xs(o: &TrainOutcome) -> Vec<f64> {
    o.eval_curve.points.iter().map(|p| p.0).collect()
}

// parity: par_step_into — pooled env stepping feeds the async collector
#[test]
fn async_runs_are_bitwise_deterministic_in_the_seed() {
    let cfg = base_cfg();
    let a = train(&cfg);
    let b = train(&cfg);
    assert!(!a.crashed);
    assert_eq!(a.eval_curve.points, b.eval_curve.points, "async reruns must match bitwise");
    assert_eq!(a.replay_fingerprint, b.replay_fingerprint, "same transition multiset");
    assert_eq!(a.updates, b.updates);
    let mut cfg2 = cfg.clone();
    cfg2.seed = 5;
    let c = train(&cfg2);
    assert_ne!(a.eval_curve.points, c.eval_curve.points, "seed must matter");
}

#[test]
fn async_matches_strict_update_count_and_eval_grid() {
    let mut cfg = base_cfg();
    cfg.sync_mode = "strict".into();
    let strict = train(&cfg);
    cfg.sync_mode = "async".into();
    let async_ = train(&cfg);
    assert!(!strict.crashed && !async_.crashed);
    assert_eq!(xs(&strict), xs(&async_), "eval step grid is sync_mode-invariant");
    assert_eq!(strict.updates, async_.updates, "1-update-per-transition count must match");
    assert!(async_.snapshot_refreshes > 0, "async must republish snapshots");
    assert_eq!(strict.snapshot_refreshes, 0, "strict has no snapshot protocol");
}

#[test]
fn seed_phase_transition_multiset_is_bitwise_strict_equal() {
    // during the seed phase actions are policy-free (per-env RNG
    // uniforms), and strict num_envs>1 uses the same per-env stream
    // layout as async — so a seed-phase-only run must fill replay with
    // the identical transition multiset under either interleave
    let mut cfg = base_cfg();
    cfg.steps = 40;
    cfg.seed_steps = 40;
    cfg.eval_every = 40;
    cfg.sync_mode = "strict".into();
    let strict = train(&cfg);
    cfg.sync_mode = "async".into();
    let async_ = train(&cfg);
    assert_ne!(strict.replay_fingerprint, 0, "sanity: replay not empty");
    assert_eq!(
        strict.replay_fingerprint, async_.replay_fingerprint,
        "seed-phase transitions must be the same multiset across interleaves"
    );
    assert_eq!(strict.updates, 0);
    assert_eq!(async_.updates, 0);
}

#[test]
fn backpressure_full_queue_blocks_collector_without_losing_updates() {
    // queue_rounds=1 with a deliberately heavy learner (large batch,
    // wider net): the collector hits the full queue every round and
    // must block, not drop or reorder; the run completes with exactly
    // the strict update count
    let mut cfg = base_cfg();
    cfg.queue_rounds = 1;
    cfg.batch = 48;
    cfg.hidden = 64;
    let async_ = train(&cfg);
    assert!(!async_.crashed);
    cfg.sync_mode = "strict".into();
    let strict = train(&cfg);
    assert_eq!(async_.updates, strict.updates, "backpressure must not change the schedule");
    assert_eq!(xs(&strict), xs(&async_));
}

#[test]
fn starved_learner_blocks_on_empty_queue_without_losing_updates() {
    // pixel collection (render-dominated) with a tiny learner: the
    // learner drains faster than the collector produces and must idle
    // on the empty queue, then resume — same update count as strict
    let mut cfg = base_cfg();
    cfg.pixels = true;
    cfg.image_size = 17;
    cfg.filters = 4;
    cfg.feature_dim = 8;
    cfg.hidden = 16;
    cfg.steps = 48;
    cfg.seed_steps = 20;
    cfg.batch = 4;
    cfg.eval_every = 48;
    cfg.num_envs = 3;
    let async_ = train(&cfg);
    assert!(!async_.crashed);
    assert!(!async_.eval_curve.points.is_empty());
    cfg.sync_mode = "strict".into();
    let strict = train(&cfg);
    assert_eq!(async_.updates, strict.updates);
}

#[test]
fn async_single_env_stream_is_supported_and_deterministic() {
    let mut cfg = base_cfg();
    cfg.num_envs = 1;
    let a = train(&cfg);
    let b = train(&cfg);
    assert!(!a.crashed);
    assert_eq!(a.eval_curve.points, b.eval_curve.points);
    // n=1 async uses the per-env stream layout, not strict's legacy
    // shared stream — the grids still align even though scores differ
    cfg.sync_mode = "strict".into();
    let strict = train(&cfg);
    assert_eq!(xs(&strict), xs(&a));
    assert_eq!(strict.updates, a.updates);
}

#[test]
fn run_many_handles_mixed_sync_modes_in_parallel() {
    // parallel grid with strict and async members: per-slot result
    // writes must keep input order, and the async member embedded in a
    // multi-threaded grid must match a solo async run bitwise
    let strict_cfg = RunConfig { sync_mode: "strict".into(), ..base_cfg() };
    let async_cfg = base_cfg();
    let outs = run_many(&[strict_cfg.clone(), async_cfg.clone()]);
    assert_eq!(outs.len(), 2);
    assert_eq!(outs[0].cfg.sync_mode, "strict");
    assert_eq!(outs[1].cfg.sync_mode, "async");
    let solo_async = train(&async_cfg);
    assert_eq!(
        outs[1].eval_curve.points, solo_async.eval_curve.points,
        "async run inside a parallel grid must match a solo async run bitwise"
    );
    let solo_strict = train(&strict_cfg);
    assert_eq!(outs[0].eval_curve.points, solo_strict.eval_curve.points);
}
