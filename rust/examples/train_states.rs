//! Train SAC from proprioceptive states on a planet-benchmark task with
//! a chosen precision preset — the paper's main experimental setting
//! (Figure 2).
//!
//! ```bash
//! cargo run --release --example train_states -- task=cartpole_swingup preset=fp16_ours steps=4000
//! ```

use lprl::config::{parse_cli, RunConfig};
use lprl::coordinator::train;
use lprl::telemetry::write_csv;

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let (_pos, kv) = parse_cli(&args);
    let mut cfg = RunConfig {
        task: "cartpole_swingup".into(),
        preset: "fp16_ours".into(),
        ..Default::default()
    };
    for (k, v) in &kv {
        if !cfg.set(k, v) {
            anyhow::bail!("unknown option {k}");
        }
    }
    println!(
        "training {} with preset {} ({} agent steps, hidden {})",
        cfg.task, cfg.preset, cfg.steps, cfg.hidden
    );
    let out = train(&cfg);
    for (x, y) in &out.eval_curve.points {
        println!("env_step {x:>8}  return {y:>8.1}");
    }
    println!("final={:.1} crashed={}", out.final_score, out.crashed);
    let path = std::path::Path::new(&cfg.out_dir)
        .join("examples")
        .join(format!("{}_{}.csv", cfg.task, cfg.preset));
    write_csv(&path, &[out.eval_curve])?;
    println!("curve written to {}", path.display());
    Ok(())
}
