//! RL from pixels (paper §4.6): conv encoder + layer-norm with the
//! paper's weight-standardization/downscale overflow guard, trained in
//! fp16 with all methods. Scaled-down defaults (21×21 frames) so it runs
//! in minutes on CPU.
//!
//! ```bash
//! cargo run --release --example train_pixels -- task=cartpole_swingup steps=1200
//! ```

use lprl::config::{parse_cli, RunConfig};
use lprl::coordinator::train;

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let (_pos, kv) = parse_cli(&args);
    let mut cfg = RunConfig {
        task: "cartpole_swingup".into(),
        preset: "fp16_ours".into(),
        pixels: true,
        steps: 1200,
        seed_steps: 200,
        batch: 16,
        hidden: 64,
        eval_every: 400,
        eval_episodes: 2,
        ..Default::default()
    };
    for (k, v) in &kv {
        if !cfg.set(k, v) {
            anyhow::bail!("unknown option {k}");
        }
    }
    println!(
        "pixel training: {}x{} frames, stack {}, {} filters, preset {}",
        cfg.image_size, cfg.image_size, cfg.frame_stack, cfg.filters, cfg.preset
    );
    let out = train(&cfg);
    for (x, y) in &out.eval_curve.points {
        println!("env_step {x:>8}  return {y:>8.1}");
    }
    println!("final={:.1} crashed={} ({:.0}s)", out.final_score, out.crashed, out.wall_secs);
    Ok(())
}
