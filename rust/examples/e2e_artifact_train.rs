//! End-to-end driver across all three layers (the repository's
//! composition proof): a Rust training loop where every gradient step
//! executes the AOT-compiled JAX+Pallas artifact via PJRT — Python never
//! runs — and the environment, replay, and action selection are native.
//!
//! Trains SAC on pendulum swing-up with the fp32 and fp16_ours variants
//! and reports the loss/return comparison (naive fp16 for contrast).
//!
//! ```bash
//! python python/compile/aot.py --out artifacts && cargo run --release --example e2e_artifact_train
//! ```

use lprl::envs::{action_repeat, make_env, sanitize_action};
use lprl::replay::{ReplayBuffer, Storage};
use lprl::rngs::Pcg64;
use lprl::runtime::TrainSession;

fn run_variant(variant: &str, env_steps: usize) -> anyhow::Result<(f64, bool)> {
    let mut sess = TrainSession::new("artifacts", variant)?;
    let (o, a, b) = sess.dims();
    let task = sess.runtime.manifest.dims.get("task").cloned().unwrap_or_default();
    let repeat = action_repeat(&task);
    let mut env = make_env(&task).ok_or_else(|| anyhow::anyhow!("bad task {task}"))?;
    anyhow::ensure!(env.obs_dim() == o && env.act_dim() == a, "artifact/env dims mismatch");

    let mut rng = Pcg64::seed(3);
    let mut replay = ReplayBuffer::new(50_000, &[o], a, Storage::F16);
    let mut obs = env.reset(&mut rng);
    let seed_steps = 200usize;
    let mut last_metrics = [0f32; 4];
    let mut crashed = false;

    let t0 = std::time::Instant::now();
    for step in 0..env_steps {
        // --- act (artifact policy after warmup) -------------------------
        let mut action = if step < seed_steps {
            (0..a).map(|_| rng.uniform_in(-1.0, 1.0)).collect::<Vec<f32>>()
        } else {
            let mut eps = vec![0.0f32; a];
            rng.normal_fill(&mut eps);
            sess.act(&obs, &eps)?
        };
        if !sanitize_action(&mut action) {
            crashed = true;
            break;
        }
        let mut rew = 0.0;
        let mut next = obs.clone();
        for _ in 0..repeat {
            let (no, r) = env.step(&action);
            next = no;
            rew += r;
        }
        replay.push(&obs, &action, rew, &next, false);
        obs = next;
        if (step + 1) % (1000 / repeat) == 0 {
            obs = env.reset(&mut rng);
        }

        // --- learn via the artifact -------------------------------------
        if step >= seed_steps && replay.len() >= b {
            let batch = replay.sample(b, &mut rng);
            let mut eps_n = vec![0.0f32; b * a];
            let mut eps_c = vec![0.0f32; b * a];
            rng.normal_fill(&mut eps_n);
            rng.normal_fill(&mut eps_c);
            last_metrics = sess.step(
                &batch.obs.data,
                &batch.act.data,
                &batch.rew,
                &batch.next_obs.data,
                &batch.not_done,
                &eps_n,
                &eps_c,
            )?;
            if !last_metrics[0].is_finite() {
                crashed = true;
                break;
            }
        }
    }
    let secs = t0.elapsed().as_secs_f64();

    // --- evaluate with the artifact policy ------------------------------
    let mut ret = 0.0f64;
    if !crashed {
        let mut eval_env = make_env(&task).unwrap();
        let mut eobs = eval_env.reset(&mut Pcg64::seed(99));
        for _ in 0..(1000 / repeat) {
            let eps = vec![0.0f32; a]; // eps = 0 -> near-mean action
            let mut action = sess.act(&eobs, &eps)?;
            if !sanitize_action(&mut action) {
                crashed = true;
                break;
            }
            for _ in 0..repeat {
                let (no, r) = eval_env.step(&action);
                eobs = no;
                ret += r as f64;
            }
        }
    }
    println!(
        "{variant:<12} steps={env_steps} critic_loss={:.4} alpha={:.4} return={ret:.1} crashed={crashed} ({secs:.1}s, {:.1} artifact-steps/s)",
        last_metrics[0],
        last_metrics[3],
        sess.steps as f64 / secs.max(1e-9)
    );
    Ok((ret, crashed))
}

fn main() -> anyhow::Result<()> {
    if !std::path::Path::new("artifacts/manifest.txt").exists() {
        anyhow::bail!("generate artifacts with `python python/compile/aot.py --out artifacts` first");
    }
    let steps: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(800);
    println!("end-to-end three-layer training (PJRT artifacts on the hot path):");
    let (r32, c32) = run_variant("fp32", steps)?;
    let (r16, c16) = run_variant("fp16_ours", steps)?;
    let (_rn, cn) = run_variant("fp16_naive", steps).map_or((0.0, true), |x| x);
    println!("\nshape check (paper): fp32 ≈ fp16_ours; naive degrades/crashes");
    println!(
        "  fp32 return {r32:.1} (crashed {c32}) | fp16_ours {r16:.1} (crashed {c16}) | naive crashed/degraded: {cn}"
    );
    Ok(())
}
