//! Quickstart: train a tiny SAC agent in fp16 with all six of the
//! paper's methods on the pendulum swing-up task, and compare against
//! naive fp16 (which fails) and the fp32 reference.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use lprl::config::RunConfig;
use lprl::coordinator::train;

fn main() {
    let mut cfg = RunConfig {
        task: "pendulum_swingup".into(),
        steps: 1500,
        seed_steps: 200,
        hidden: 64,
        batch: 64,
        eval_every: 500,
        eval_episodes: 2,
        ..Default::default()
    };

    for preset in ["fp32", "fp16_ours", "fp16_naive"] {
        cfg.preset = preset.into();
        let out = train(&cfg);
        println!("--- {preset} ---");
        for (x, y) in &out.eval_curve.points {
            println!("  step {x:>6}  return {y:>7.1}");
        }
        println!(
            "  final {:.1}  crashed={}  skipped opt steps={}  ({:.1}s)",
            out.final_score, out.crashed, out.skipped_steps, out.wall_secs
        );
    }
    println!("\nExpected shape (paper Fig. 1/2): fp16_ours tracks fp32;");
    println!("fp16_naive flatlines or crashes to 0.");
}
