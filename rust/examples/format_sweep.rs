//! Figure 4 in miniature: sweep the significand width of an e5mX format
//! (our qtorch replacement) and watch SAC degrade gracefully, then
//! collapse — entirely in the native Rust engine.
//!
//! ```bash
//! cargo run --release --example format_sweep -- steps=2000 task=pendulum_swingup
//! ```

use lprl::config::{parse_cli, RunConfig};
use lprl::coordinator::{run_many, train};
use lprl::lowp::{e5m, Precision};

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let (_pos, kv) = parse_cli(&args);
    let mut base = RunConfig {
        task: "pendulum_swingup".into(),
        steps: 2000,
        eval_every: 1000,
        eval_episodes: 2,
        ..Default::default()
    };
    for (k, v) in &kv {
        let _ = base.set(k, v);
    }

    // print format properties first — the lowp module at work
    println!("{:<8} {:>12} {:>14} {:>12}", "format", "max", "min_subnormal", "epsilon");
    for m in (5..=10).rev() {
        let f = e5m(m);
        println!(
            "e5m{m:<5} {:>12.1} {:>14.3e} {:>12.3e}",
            f.max_value(),
            f.min_subnormal(),
            f.epsilon()
        );
    }

    let mut cfgs = Vec::new();
    for m in (5..=10).rev() {
        let mut c = base.clone();
        c.preset = format!("e5m{m}_ours");
        assert!(Precision::parse(&format!("e5m{m}")).is_some());
        cfgs.push(c);
    }
    let fp32 = {
        let mut c = base.clone();
        c.preset = "fp32".into();
        train(&c)
    };
    let outs = run_many(&cfgs);
    println!("\n{:<12} {:>10} {:>8}", "preset", "return", "crashed");
    println!("{:<12} {:>10.1} {:>8}", "fp32", fp32.final_score, fp32.crashed);
    for o in &outs {
        println!("{:<12} {:>10.1} {:>8}", o.cfg.preset, o.final_score, o.crashed);
    }
    println!("\nExpected shape (paper Fig. 4): monotone degradation, collapse near m=5.");
    Ok(())
}
