//! Minimal offline stand-in for the [`anyhow`](https://docs.rs/anyhow)
//! crate: the build environment has no registry access, so the workspace
//! vendors the small API subset the codebase uses — [`Error`],
//! [`Result`], the [`anyhow!`] / [`bail!`] / [`ensure!`] macros, and the
//! [`Context`] extension trait.
//!
//! Semantics follow real anyhow where it matters here:
//! * `Display` prints the outermost message; `{:#}` prints the whole
//!   cause chain joined with `": "`.
//! * `?` converts any `std::error::Error + Send + Sync + 'static` into
//!   [`Error`], capturing its source chain.
//! * `Error` deliberately does **not** implement `std::error::Error`
//!   (same as real anyhow), which is what makes the blanket `From` legal.

use std::fmt;

/// A dynamic error: an outermost message plus a cause chain.
pub struct Error {
    /// `chain[0]` is the outermost (most recent context) message.
    chain: Vec<String>,
}

impl Error {
    /// Build an error from a printable message.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { chain: vec![message.to_string()] }
    }

    /// Wrap with an outer context message (what `Context::context` does).
    pub fn context<C: fmt::Display>(mut self, context: C) -> Error {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The cause chain, outermost first (for diagnostics/tests).
    pub fn chain_messages(&self) -> &[String] {
        &self.chain
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.chain[0])
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain[0])?;
        if self.chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for (i, c) in self.chain[1..].iter().enumerate() {
                write!(f, "\n    {i}: {c}")?;
            }
        }
        Ok(())
    }
}

impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(e: E) -> Error {
        let mut chain = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Error { chain }
    }
}

/// `anyhow::Result<T>`: `std::result::Result` defaulted to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Extension trait adding `.context(..)` / `.with_context(..)`.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E> Context<T> for std::result::Result<T, E>
where
    Error: From<E>,
{
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| Error::from(e).context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error::from(e).context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string or any `Display` value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(::std::format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(::std::format!($fmt, $($arg)*))
    };
}

/// Early-return with an error built like [`anyhow!`].
#[macro_export]
macro_rules! bail {
    ($($t:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($t)*))
    };
}

/// Early-return with an error unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            $crate::bail!("condition failed: {}", ::std::stringify!($cond));
        }
    };
    ($cond:expr, $($t:tt)*) => {
        if !($cond) {
            $crate::bail!($($t)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "missing file")
    }

    #[test]
    fn display_plain_and_alternate() {
        let e: Error = Error::from(io_err()).context("reading manifest");
        assert_eq!(format!("{e}"), "reading manifest");
        assert_eq!(format!("{e:#}"), "reading manifest: missing file");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn parse(s: &str) -> Result<usize> {
            Ok(s.parse::<usize>()?)
        }
        assert_eq!(parse("42").unwrap(), 42);
        assert!(parse("nope").is_err());
    }

    #[test]
    fn macros_build_errors() {
        let name = "x";
        let e = anyhow!("unknown artifact {name}");
        assert_eq!(format!("{e}"), "unknown artifact x");
        let e = anyhow!(String::from("plain"));
        assert_eq!(format!("{e}"), "plain");
        let e = anyhow!("{} + {}", 1, 2);
        assert_eq!(format!("{e}"), "1 + 2");

        fn f(flag: bool) -> Result<u32> {
            ensure!(flag, "flag must be set");
            if flag {
                Ok(1)
            } else {
                bail!("unreachable {}", 0)
            }
        }
        assert_eq!(f(true).unwrap(), 1);
        assert_eq!(format!("{}", f(false).unwrap_err()), "flag must be set");
    }

    #[test]
    fn option_context() {
        let v: Option<u32> = None;
        let e = v.context("empty").unwrap_err();
        assert_eq!(format!("{e}"), "empty");
        assert_eq!(Some(3u32).with_context(|| "never").unwrap(), 3);
    }

    #[test]
    fn debug_shows_cause_chain() {
        let e = Error::from(io_err()).context("outer");
        let d = format!("{e:?}");
        assert!(d.contains("outer") && d.contains("Caused by") && d.contains("missing file"));
    }
}
